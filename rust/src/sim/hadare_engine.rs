//! HadarE's round engine over *forked* jobs (paper §V), shared between the
//! pure simulation (CRU/TTD/JCT figures) and the PJRT-backed emulation
//! (which layers real training on the same schedule via `exec`).
//!
//! Per round: the HadarE planner assigns gang slots to copies — a whole
//! node by default, one `(node, pool)` sub-gang under
//! [`GangConfig::share_nodes`] (partial-node mode, so two parents can
//! share a big node); the Job Tracker divides each parent's remaining
//! steps across its scheduled copies in proportion to the **sub-gang**
//! throughput of what each copy actually booked
//! ([`crate::sched::hadare::alloc_throughput`]: bottleneck rule +
//! sub-linear intra-node scaling, §V-B); copies burn their share (bounded
//! by gang slot capacity and the restart overhead); the tracker
//! aggregates completed steps. A parent finishes the moment its
//! aggregated steps reach the target — possibly mid-slot ("early finish",
//! §V-A). Copies run *concurrently*, so the finish instant is the **max**
//! busy end-time across the parent's copies that round, not whichever
//! copy's report happened to cross the threshold.
//!
//! Parents are admitted by **arrival**: the planner filters parents whose
//! `arrival` lies beyond the round start, so a staggered trace produces
//! no work before a job exists.
//!
//! Accounting is **per GPU**: a busy 4-GPU sub-gang contributes 4
//! GPU-seconds per second to `busy_gpu_secs` and 4 × `slot_secs` to
//! `alloc_gpu_secs`, so GRU/CRU/ANU measure the actual 60-GPU `sim60`
//! cluster rather than its 15 nodes — and, in partial-node mode, each
//! pool of a shared big node books its own GPU-seconds.
//!
//! Restart overhead is charged when a `(node, pool)` switches *parents*
//! (a model load); a pool that idles a round keeps its loaded model, so
//! resuming the same parent later is free. Under whole-node gangs every
//! pool of the node carries the same binding, which degenerates to the
//! historical per-node bookkeeping.

use crate::cluster::events::{ClusterTimeline, EventTimeline};
use crate::cluster::gpu::GpuType;
use crate::cluster::spec::ClusterSpec;
use crate::forking::forker::{fork, ForkIds};
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::jobs::queue::JobQueue;
use crate::obs;
use crate::obs::export::{RoundTelemetry, TelemetrySink};
use crate::sched::hadare::{alloc_throughput, GangConfig, HadarE,
                           PrevRound};
use crate::sched::RoundCtx;
use crate::sim::engine::{
    integrate_capacity, RoundJob, RoundRecord, SimConfig, SimResult,
};
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

/// What one copy did in one round — the hook `exec` uses to run real
/// training steps for the same schedule.
#[derive(Clone, Debug)]
pub struct CopyWork {
    /// Round number (0-based).
    pub round: u64,
    /// Copy job id (see [`crate::forking::forker::ForkIds`]).
    pub copy: JobId,
    /// The copy's parent job.
    pub parent: JobId,
    /// Node that hosted the copy this round.
    pub node: usize,
    /// GPUs in the copy's sub-gang (the whole node by default, one pool
    /// in partial-node mode).
    pub gpus: usize,
    /// The pool the copy occupied: `Some(type)` when the allocation sat
    /// on a single GPU pool (always the case in partial-node mode, and
    /// for whole-node gangs on single-type nodes); `None` when a
    /// whole-node gang spanned several pools.
    pub pool: Option<GpuType>,
    /// Steps this copy's sub-gang completed this round.
    pub steps: f64,
    /// Seconds of the slot the sub-gang was busy (per gang, not per
    /// GPU — multiply by [`CopyWork::gpus`] for GPU-seconds).
    pub busy_secs: f64,
}

/// HadarE simulation outcome: the usual metrics plus the per-round copy
/// work log.
pub struct HadarESimResult {
    /// The scheduling metrics (same shape as the generic engine's).
    pub sim: SimResult,
    /// Per-(round, copy, node) work records.
    pub work_log: Vec<CopyWork>,
}

/// Run HadarE over `parents` on a *static* `cluster`. `copies` defaults
/// to the node count (Theorem 3's optimum) when `None`.
pub fn run(parents: &[Job], cluster: &ClusterSpec, cfg: &SimConfig,
           copies: Option<u64>) -> HadarESimResult {
    run_with_events(parents, cluster, &EventTimeline::empty(), cfg, copies)
        .expect("the empty event timeline always resolves")
}

/// [`run_with_gang`] with the default whole-node [`GangConfig`].
pub fn run_with_events(parents: &[Job], cluster: &ClusterSpec,
                       events: &EventTimeline, cfg: &SimConfig,
                       copies: Option<u64>)
                       -> Result<HadarESimResult, String> {
    run_with_gang(parents, cluster, events, cfg, copies,
                  GangConfig::default())
}

/// Run HadarE under a cluster event timeline with explicit gang-model
/// knobs (pass [`GangConfig::shared`] for partial-node / per-pool
/// gangs): due events apply at round boundaries, node drains unbind the
/// copies running there (counted as preemptions; the pool's next model
/// load pays the restart overhead), and the planner sees the current
/// node inventory every round. The copy budget stays at the *initial*
/// node count unless `copies` is given — under heavy joins, pass a
/// larger budget to keep every node busy.
pub fn run_with_gang(parents: &[Job], cluster: &ClusterSpec,
                     events: &EventTimeline, cfg: &SimConfig,
                     copies: Option<u64>, gang: GangConfig)
                     -> Result<HadarESimResult, String> {
    run_with_gang_observed(parents, cluster, events, cfg, copies, gang, None)
}

/// [`run_with_gang`] plus telemetry: when `sink` is given, one
/// [`RoundTelemetry`] record is emitted per round (job counts are
/// *parents*, GPU counts are copy sub-gangs). Observation never perturbs
/// plans — same contract as [`crate::sim::engine::run_observed`].
pub fn run_with_gang_observed(parents: &[Job], cluster: &ClusterSpec,
                              events: &EventTimeline, cfg: &SimConfig,
                              copies: Option<u64>, gang: GangConfig,
                              mut sink: Option<&mut TelemetrySink>)
                              -> Result<HadarESimResult, String> {
    let mut view = ClusterTimeline::new(cluster, events)?;
    let n_nodes = cluster.nodes.len() as u64;
    let copies = copies.unwrap_or(n_nodes).max(1);
    let ids = ForkIds {
        max_job_count: parents
            .iter()
            .map(|j| j.id.0 + 1)
            .max()
            .unwrap_or(1)
            .max(64),
    };
    let mut tracker = JobTracker::new(ids);
    let mut queue = JobQueue::new();
    for p in parents {
        // Admit before registering so a duplicate parent id surfaces as
        // a simulation error without leaving a half-registered tracker.
        queue
            .admit(p.clone())
            .map_err(|e| format!("admitting parent failed: {e}"))?;
        let copy_jobs = fork(p, copies, ids);
        tracker.register(
            p.id,
            p.total_iters(),
            &copy_jobs.iter().map(|c| c.id).collect::<Vec<_>>(),
        );
    }

    let mut planner = HadarE::with_gang(copies, gang);
    let nominal_gpus = cluster.total_gpus() as f64;
    let mut now = 0.0;
    let mut round = 0u64;
    let mut busy_total = 0.0;
    let mut alloc_total = 0.0;
    // Capacity step function (segment start, available GPUs) for ANU.
    let mut avail_log: Vec<(f64, f64)> = vec![(0.0, nominal_gpus)];
    let mut preemptions = 0u64;
    let mut last_finish: f64 = 0.0;
    let mut sched_wall = 0.0;
    let mut timeline = Vec::new();
    let mut work_log = Vec::new();
    // Per-parent first-seen finish time.
    let mut finish: BTreeMap<JobId, f64> = BTreeMap::new();
    // Copy most recently bound to each (node, pool) — restart-overhead
    // bookkeeping. Entries persist while a pool idles — the model stays
    // loaded — and are dropped only when the node drains. Whole-node
    // gangs bind every pool of the host to the same copy, so on
    // single-pool nodes this is the historical per-node table.
    let mut prev_binding: BTreeMap<(usize, GpuType), JobId> = BTreeMap::new();
    // Previous round's allocations, kept only while telemetry is being
    // written (`plan_changed` needs them; the planner itself is
    // stateless about plan diffs).
    let mut prev_allocs = None;

    while !tracker.all_complete() && round < cfg.max_rounds {
        let _round_span = obs::trace::span("sim.round");
        let events_before = view.events_applied();
        let preempts_before = preemptions;
        // Apply cluster events due by this round boundary; drained nodes
        // lose their copy bindings (the tracker keeps the parents'
        // aggregated steps — HadarE is naturally churn-tolerant).
        let event_span = obs::trace::span("sim.events");
        let change = view.advance_to(now);
        if change.capacity_changed {
            avail_log.push((now, view.cluster().total_gpus() as f64));
        }
        if !change.affected.is_empty() {
            let drained: Vec<(usize, GpuType)> = prev_binding
                .keys()
                .copied()
                .filter(|(h, _)| change.affected.contains(h))
                .collect();
            // One preemption per distinct still-running (node, parent)
            // unbound — the historical per-node count. A whole-node gang
            // on a two-pool node is one preemption; a shared node
            // carrying two parents' pools is two; and a parent whose
            // live copy migrated pools within the node (leaving a stale
            // binding of an older copy on the idle pool) is still one,
            // not two.
            let mut preempted: BTreeSet<(usize, JobId)> = BTreeSet::new();
            for key in drained {
                if let Some(copy) = prev_binding.remove(&key) {
                    // Bindings of already-finished parents are stale —
                    // dropping them disturbs no running work.
                    if !tracker.is_parent_complete(copy) {
                        preempted.insert((key.0, tracker.resolve(copy)));
                    }
                }
            }
            preemptions += preempted.len() as u64;
            // One delta entry per distinct preempted parent (a parent
            // unbound on several nodes is still one queue-level
            // preemption).
            let parents_hit: BTreeSet<JobId> =
                preempted.iter().map(|&(_, p)| p).collect();
            for p in parents_hit {
                queue.note_preempted(p);
            }
        }
        drop(event_span);

        // Delta production: drain this boundary's arrivals into the
        // persistent waiting set and fold in buffered completions /
        // preemptions plus the cluster events just applied. The HadarE
        // round loop never skips boundaries, so each round consumes its
        // own boundary delta directly. O(changes), not O(parents).
        let mut delta = queue.poll_round(now);
        delta.events = view.events_applied() - events_before;
        let active = queue.waiting();
        // Hand the planner the binding carry-over, resolved to parent
        // ids: warm start (fewer rescored rows) + switch-cost-aware
        // payoffs, with the same `restart_overhead` the engine charges
        // below — the planner now optimises against the cost model it
        // is billed under.
        let prev = {
            let mut p = PrevRound::new(cfg.restart_overhead);
            for (&(node, g), &copy) in &prev_binding {
                p.bind(node, g, tracker.resolve(copy));
            }
            p
        };
        let (plan, round_wall) = {
            let ctx = RoundCtx {
                round,
                now,
                slot_secs: cfg.slot_secs,
                horizon: cfg.horizon,
                queue: &queue,
                active: &active,
                delta: Some(&delta),
                cluster: view.cluster(),
            };
            // lint: allow(wall-clock, reason = "sched_wall telemetry only; the timing feeds SimResult reporting, never planning decisions")
            let t0 = Instant::now();
            let plan = {
                let _s = obs::trace::span("sched.schedule");
                planner.plan_round_with(&ctx, &tracker, &prev)
            };
            let dt = t0.elapsed().as_secs_f64();
            sched_wall += dt;
            (plan, dt)
        };

        // Group scheduled copies by parent. A copy's allocation spans
        // exactly one node — several pools of it for a whole-node gang,
        // a single pool in partial-node mode — and is rated by what it
        // actually booked (`alloc_throughput`), so shares stay
        // sub-gang-accurate in both modes.
        struct Assigned {
            copy: JobId,
            node: usize,
            gpus: usize,
            /// The allocation's pools on the host (binding keys).
            pools: Vec<GpuType>,
            /// Sub-gang throughput of the allocation.
            x: f64,
        }
        let mut per_parent: BTreeMap<JobId, Vec<Assigned>> = BTreeMap::new();
        for (&copy, alloc) in &plan.allocations {
            let parent = tracker.resolve(copy);
            let job = queue.get(parent).expect("parent job");
            let node_id = alloc
                .nodes()
                .first()
                .copied()
                .expect("plan allocations are non-empty");
            per_parent.entry(parent).or_default().push(Assigned {
                copy,
                node: node_id,
                gpus: alloc.total_gpus(),
                pools: alloc.gpu_types(),
                x: alloc_throughput(job, alloc, &planner.gang),
            });
        }

        let mut rec = RoundRecord {
            round,
            start: now,
            jobs: BTreeMap::new(),
            busy_gpu_secs: 0.0,
            alloc_gpu_secs: 0.0,
            avail_gpu_secs: view.cluster().total_gpus() as f64
                * cfg.slot_secs,
        };
        let mut restart_charges = 0u64;
        let mut completed_count = 0usize;
        for (parent, assigned) in &per_parent {
            let throughputs: Vec<f64> =
                assigned.iter().map(|a| a.x).collect();
            let shares =
                tracker.divide_steps(*parent, &throughputs, cfg.slot_secs);
            let remaining_before =
                tracker.parent(*parent).map(|p| p.remaining()).unwrap_or(0.0);
            rec.jobs.insert(
                *parent,
                RoundJob {
                    gpus: assigned.iter().map(|a| a.gpus).sum(),
                    remaining_before,
                    progressed: 0.0, // filled below as copies report
                    node: assigned.first().map(|a| a.node).unwrap_or(0),
                },
            );
            // Busy end-time (offset from round start) of the latest copy
            // that advanced steps. Copies run concurrently, so a parent's
            // early finish is the *max* end across its copies this round
            // — not whichever copy's report happened to cross the
            // completion threshold in iteration order, which could
            // under-report TTD/JCT by up to nearly a slot.
            let mut last_end = 0.0f64;
            for (a, &share) in assigned.iter().zip(shares.iter()) {
                // Restart overhead when the (node, pool) switches
                // *parents* — a model load. Which copy id carries the
                // parent is irrelevant, and a pool that idled keeps its
                // model, so resuming the same parent later is free.
                let switched = a.pools.iter().any(|g| {
                    prev_binding
                        .get(&(a.node, *g))
                        .map(|c| tracker.resolve(*c))
                        != Some(*parent)
                });
                if switched {
                    restart_charges += 1;
                }
                let overhead =
                    if switched { cfg.restart_overhead } else { 0.0 };
                let eff = (cfg.slot_secs - overhead).max(0.0);
                let steps = share.min(a.x * eff);
                let busy = if a.x > 0.0 { steps / a.x } else { 0.0 };
                tracker.report_steps(a.copy, steps);
                rec.busy_gpu_secs += busy * a.gpus as f64;
                rec.alloc_gpu_secs += cfg.slot_secs * a.gpus as f64;
                if let Some(rj) = rec.jobs.get_mut(parent) {
                    rj.progressed += steps;
                }
                if steps > 0.0 {
                    last_end = last_end.max(overhead + busy);
                }
                work_log.push(CopyWork {
                    round,
                    copy: a.copy,
                    parent: *parent,
                    node: a.node,
                    gpus: a.gpus,
                    pool: if a.pools.len() == 1 {
                        Some(a.pools[0])
                    } else {
                        None
                    },
                    steps,
                    busy_secs: busy,
                });
                // Idle pools keep their previous binding (model stays
                // loaded); only pools used this round rebind.
                for &g in &a.pools {
                    prev_binding.insert((a.node, g), a.copy);
                }
            }
            // Parent finishing mid-slot: early finish, stamped at the
            // max copy end-time. Notify the planner (same completion
            // protocol as the generic engine's
            // [`crate::sched::Scheduler::job_completed`]) so any
            // per-parent planner state is dropped exactly once.
            if tracker.is_parent_complete(*parent)
                && !finish.contains_key(parent)
            {
                let f = now + last_end;
                finish.insert(*parent, f);
                last_finish = last_finish.max(f);
                completed_count += 1;
                planner.job_completed(*parent);
                // Through the queue so the waiting-set index and the
                // next round's delta see the completion.
                queue.complete(*parent, f);
            }
        }

        if obs::enabled() {
            let m = obs::metrics::core();
            m.sim_rounds.add(1);
            m.sim_queue_depth.set(active.len() as f64);
            m.sim_active_jobs.set(active.len() as f64);
            m.sim_delta_arrivals.add(delta.arrivals.len() as u64);
            m.sim_delta_completions.add(delta.completions.len() as u64);
            m.sim_preemptions.add(preemptions - preempts_before);
            m.sim_restart_charges.add(restart_charges);
            m.sched_round_secs.record(round_wall);
        }
        if let Some(s) = sink.as_deref_mut() {
            let plan_changed = prev_allocs.as_ref() != Some(&plan.allocations);
            let t = RoundTelemetry {
                round,
                now,
                scheduler: if gang.share_nodes {
                    "hadare-shared".to_string()
                } else {
                    "hadare".to_string()
                },
                active_jobs: active.len(),
                scheduled_jobs: per_parent.len(),
                gpus_allocated: plan
                    .allocations
                    .values()
                    .map(|a| a.total_gpus())
                    .sum(),
                busy_gpu_secs: rec.busy_gpu_secs,
                alloc_gpu_secs: rec.alloc_gpu_secs,
                avail_gpu_secs: rec.avail_gpu_secs,
                plan_changed,
                preemptions: preemptions - preempts_before,
                events_applied: view.events_applied() - events_before,
                completed: completed_count,
                solver: None,
                sched_wall_secs: round_wall,
            };
            s.emit(&t)
                .map_err(|e| format!("telemetry write failed: {e}"))?;
            prev_allocs = Some(plan.allocations.clone());
        }

        busy_total += rec.busy_gpu_secs;
        timeline.push(rec);
        round += 1;
        now += cfg.slot_secs;
    }

    // Finished parents already went through [`JobQueue::complete`]
    // (status + finish time); stamp their progress and collect metrics.
    let mut jct = BTreeMap::new();
    let mut finish_times = Vec::new();
    for job in queue.iter_mut() {
        if let Some(&f) = finish.get(&job.id) {
            job.progress = job.total_iters();
            jct.insert(job.id, f - job.arrival);
            finish_times.push(f);
        }
    }
    finish_times.sort_by(|a, b| a.total_cmp(b));
    let ttd = if last_finish > 0.0 { last_finish } else { now };
    // CRU denominator: allocated node-slots, with the final slot clamped
    // at the batch finish (a node is not "allocated" past the experiment).
    for rec in &timeline {
        let span = (ttd - rec.start).clamp(0.0, cfg.slot_secs);
        alloc_total += rec.alloc_gpu_secs / cfg.slot_secs * span;
    }
    let avail_total = integrate_capacity(&avail_log, ttd);
    obs::trace::flush();
    Ok(HadarESimResult {
        sim: SimResult {
            scheduler: if gang.share_nodes {
                "hadare-shared".to_string()
            } else {
                "hadare".to_string()
            },
            ttd,
            jct,
            finish_times,
            gru: if ttd > 0.0 {
                busy_total / (nominal_gpus * ttd)
            } else {
                0.0
            },
            cru: if alloc_total > 0.0 {
                busy_total / alloc_total
            } else {
                0.0
            },
            anu: if avail_total > 0.0 {
                busy_total / avail_total
            } else {
                0.0
            },
            rounds: round,
            preemptions,
            events_applied: view.events_applied(),
            sched_wall_secs: sched_wall,
            sched_wall_per_round: if round > 0 {
                sched_wall / round as f64
            } else {
                0.0
            },
            timeline,
            change_fraction: 0.0,
            solver: None,
        },
        work_log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::GpuType;
    use crate::jobs::model::DlModel;
    use crate::jobs::throughput;
    use crate::trace::workload::{cluster_gpu_pcie, physical_jobs};

    fn cfg() -> SimConfig {
        SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 5000,
            horizon: 1e7,
        }
    }

    #[test]
    fn completes_m5_mix_on_testbed() {
        let cluster = ClusterSpec::testbed5();
        let jobs = physical_jobs("M-5", &cluster, 1.0).unwrap();
        let res = run(&jobs, &cluster, &cfg(), None);
        assert_eq!(res.sim.jct.len(), 5, "all five parents complete");
        assert!(res.sim.gru > 0.5, "gru={}", res.sim.gru);
    }

    #[test]
    fn single_job_uses_all_nodes_and_beats_single_node() {
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 30, 100);
        j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
        let res5 = run(std::slice::from_ref(&j), &cluster, &cfg(), None);
        let res1 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(1));
        assert!(res5.sim.ttd < res1.sim.ttd,
                "forking speeds up: {} vs {}", res5.sim.ttd, res1.sim.ttd);
        // First round uses all five nodes.
        let first_round_nodes: std::collections::BTreeSet<usize> = res5
            .work_log
            .iter()
            .filter(|w| w.round == 0)
            .map(|w| w.node)
            .collect();
        assert_eq!(first_round_nodes.len(), 5);
    }

    #[test]
    fn more_copies_never_hurt_cru_theorem3() {
        // Theorem 3: CRU_1 < CRU_x < CRU_n = CRU_{n+j}. The interior
        // inequalities are *strict* — every extra copy below the node
        // count puts another (usable) node to work, and Transformer has a
        // positive rate on all five testbed types, so the assertions
        // match the theorem rather than allowing a hidden tie.
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut j = Job::new(0, DlModel::Transformer, 0.0, 1, 40, 100);
        j.throughput =
            throughput::throughput_row(DlModel::Transformer, &pairs);
        let g1 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(1)).sim.gru;
        let g3 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(3)).sim.gru;
        let g5 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(5)).sim.gru;
        let g7 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(7)).sim.gru;
        assert!(g1 < g3, "{g1} !< {g3}");
        assert!(g3 < g5, "{g3} !< {g5}");
        assert!((g5 - g7).abs() < 0.05, "n vs n+j: {g5} vs {g7}");
    }

    #[test]
    fn early_finish_is_stamped_at_the_latest_copy_end() {
        // Regression (engine timing): a parent's finish used to be
        // stamped from whichever copy's `report_steps` crossed the
        // completion threshold in iteration order. Copies run
        // concurrently, so the finish is the *max* busy end-time across
        // the parent's copies that round — here the overhead-paying copy
        // ends at +100 s while the threshold-crossing copy ends at +90 s,
        // and the buggy stamp under-reported JCT/TTD by the 10 s restart
        // overhead.
        use crate::cluster::gpu::PcieGen;
        use crate::cluster::node::Node;
        let cluster = ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "k", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        );
        let cfg = SimConfig {
            slot_secs: 100.0,
            restart_overhead: 10.0,
            max_rounds: 100,
            horizon: 1e7,
        };
        // P0: 360 iters at V100=2 / K80=1 it/s. P1: 400 iters, V100 only
        // (more remaining, so it wins the fast node in round 0 and P0
        // starts on the K80 node).
        let mut p0 = Job::new(0, DlModel::Lstm, 0.0, 1, 4, 90);
        p0.set_throughput(GpuType::V100, 2.0);
        p0.set_throughput(GpuType::K80, 1.0);
        let mut p1 = Job::new(1, DlModel::Lstm, 0.0, 1, 4, 100);
        p1.set_throughput(GpuType::V100, 5.0);
        let res = run(&[p0, p1], &cluster, &cfg, Some(2));
        // Round 0: P1 finishes on the V100 node (10 + 80 s); P0 burns 90
        // steps on the K80 node (270 left). Round 1: P0's copy 1 moves to
        // the V100 node (switch: 10 s overhead, 180 steps in 90 s busy,
        // end +100 s) while copy 2 stays on the K80 node (no overhead, 90
        // steps, end +90 s). The threshold crosses at copy 2, but the
        // parent is only done when copy 1's gang drains at 100 + 100 s.
        assert!((res.sim.jct[&JobId(1)] - 90.0).abs() < 1e-9,
                "P1 jct: {}", res.sim.jct[&JobId(1)]);
        assert!((res.sim.jct[&JobId(0)] - 200.0).abs() < 1e-9,
                "P0 finish must wait for the overhead-paying copy: {}",
                res.sim.jct[&JobId(0)]);
        assert!((res.sim.ttd - 200.0).abs() < 1e-9, "ttd: {}", res.sim.ttd);
    }

    #[test]
    fn staggered_arrivals_produce_no_work_before_arrival() {
        // Regression (arrival handling): the engine registers every
        // parent with the tracker up front, and the planner used to
        // iterate all registered parents — a parent with `arrival > 0`
        // trained before it existed. Now arrival gates planning: no
        // work-log row may precede a parent's arrival.
        use crate::cluster::gpu::PcieGen;
        use crate::cluster::node::Node;
        let cluster = ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "k", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        );
        let cfg = SimConfig {
            slot_secs: 100.0,
            restart_overhead: 10.0,
            max_rounds: 1000,
            horizon: 1e7,
        };
        let mut p0 = Job::new(0, DlModel::Lstm, 0.0, 1, 20, 100);
        p0.set_throughput(GpuType::V100, 2.0);
        p0.set_throughput(GpuType::K80, 1.0);
        // Arrives mid-round-1: first plannable round boundary is t=200.
        let mut p1 = Job::new(1, DlModel::Lstm, 150.0, 1, 5, 100);
        p1.set_throughput(GpuType::V100, 2.0);
        p1.set_throughput(GpuType::K80, 1.0);
        let arrival = p1.arrival;
        let res = run(&[p0, p1], &cluster, &cfg, Some(2));
        assert_eq!(res.sim.jct.len(), 2, "both parents complete");
        for w in res.work_log.iter().filter(|w| w.parent == JobId(1)) {
            let round_start = w.round as f64 * cfg.slot_secs;
            assert!(round_start >= arrival,
                    "work for parent 1 at t={round_start} before its \
                     arrival at {arrival}: {w:?}");
        }
        // JCT is measured from arrival, and the parent cannot finish
        // before it starts.
        let f1 = res.sim.jct[&JobId(1)] + arrival;
        assert!(f1 > 200.0, "parent 1 finishes after its first round: {f1}");
    }

    #[test]
    fn maintenance_window_preempts_bound_copies_and_completes() {
        use crate::cluster::events::{EventKind, EventTimeline};
        let cluster = ClusterSpec::testbed5();
        // 3x the paper-scale epochs: enough work that the run is still
        // going when the node rejoins at t=270 (round 3).
        let jobs = physical_jobs("M-3", &cluster, 3.0).unwrap();
        let mut events = EventTimeline::empty();
        // Drain the fastest node for two slots starting at round 1.
        events.push(90.0, EventKind::Maintenance { node: 3, duration: 180.0 });
        let res =
            run_with_events(&jobs, &cluster, &events, &cfg(), None).unwrap();
        assert_eq!(res.sim.jct.len(), 3, "all parents complete despite churn");
        // HadarE keeps every node busy, so the drained node had a copy.
        assert!(res.sim.preemptions >= 1);
        // leave + rejoin.
        assert_eq!(res.sim.events_applied, 2);
        // No work lands on node 3 while it is away (rounds 1 and 2).
        for w in res.work_log.iter().filter(|w| w.round == 1 || w.round == 2)
        {
            assert_ne!(w.node, 3, "round {} used a drained node", w.round);
        }
        // Capacity only ever shrinks here, so the availability-normalised
        // figure is at least the nominal one.
        assert!(res.sim.anu >= res.sim.gru - 1e-12);
    }

    #[test]
    fn work_log_steps_match_tracker_totals() {
        // Gang throughput must not break §V-B conservation: summed
        // work-log steps equal each parent's total, on the single-GPU
        // testbed and the multi-GPU sim60 alike.
        for cluster in [ClusterSpec::testbed5(), ClusterSpec::sim60()] {
            let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
            let res = run(&jobs, &cluster, &cfg(), None);
            let mut per_parent: BTreeMap<JobId, f64> = BTreeMap::new();
            for w in &res.work_log {
                *per_parent.entry(w.parent).or_insert(0.0) += w.steps;
            }
            for j in &jobs {
                let done = per_parent.get(&j.id).copied().unwrap_or(0.0);
                assert!((done - j.total_iters()).abs() < 1e-6,
                        "{}: parent {} steps {} vs {}", cluster.name, j.id,
                        done, j.total_iters());
            }
        }
    }

    #[test]
    fn sim60_round0_allocates_all_60_gpus() {
        // The bugfix, engine-level: with unfinished parents, round 0
        // books 60 GPU-slots (4 per node on all 15 nodes) — the pre-gang
        // engine booked 15 and let 45 GPUs idle against `nominal_gpus =
        // 60` in GRU.
        let cluster = ClusterSpec::sim60();
        let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
        let res = run(&jobs, &cluster, &cfg(), None);
        let r0 = &res.sim.timeline[0];
        assert!((r0.alloc_gpu_secs - 60.0 * 90.0).abs() < 1e-6,
                "round 0 allocates every GPU: {}", r0.alloc_gpu_secs);
        let mut gpus_by_node: BTreeMap<usize, usize> = BTreeMap::new();
        for w in res.work_log.iter().filter(|w| w.round == 0) {
            *gpus_by_node.entry(w.node).or_insert(0) += w.gpus;
        }
        assert_eq!(gpus_by_node.len(), 15, "every node hosts a copy");
        assert!(gpus_by_node.values().all(|&g| g == 4),
                "each copy takes the node's whole 4-GPU gang");
        assert_eq!(res.sim.jct.len(), 3, "all parents complete");
    }

    #[test]
    fn theorem3_gru_monotone_on_multi_gpu_cluster() {
        // Theorem 3 re-asserted on sim60: GRU_1 < GRU_x < GRU_n, and a
        // budget beyond the node count changes nothing (one copy per
        // node per parent).
        let cluster = ClusterSpec::sim60();
        let mut j = Job::new(0, DlModel::Transformer, 0.0, 1, 500, 100);
        j.set_throughput(GpuType::V100, 3.0);
        j.set_throughput(GpuType::P100, 2.0);
        j.set_throughput(GpuType::K80, 1.0);
        let gru = |copies: u64| {
            run(std::slice::from_ref(&j), &cluster, &cfg(), Some(copies))
                .sim
                .gru
        };
        let g1 = gru(1);
        let g5 = gru(5);
        let g15 = gru(15);
        let g20 = gru(20);
        assert!(g1 < g5, "{g1} !< {g5}");
        assert!(g5 < g15, "{g5} !< {g15}");
        assert!((g15 - g20).abs() < 1e-12,
                "budget beyond node count is inert: {g15} vs {g20}");
        assert!(g15 > 0.9, "full fan-out keeps ~every GPU busy: {g15}");
    }

    #[test]
    fn big8_shared_round0_books_every_gpu_across_shared_nodes() {
        // Partial-node occupancy, engine-level: with three active parents
        // on the two-pool big-node preset, per-pool gangs book all 32
        // GPUs in round 0 and every node hosts pools of two *different*
        // parents (a parent never holds two pools of one node).
        let cluster = ClusterSpec::big8();
        let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
        let res = run_with_gang(&jobs, &cluster, &EventTimeline::empty(),
                                &cfg(), None, GangConfig::shared())
            .unwrap();
        let r0 = &res.sim.timeline[0];
        assert!((r0.alloc_gpu_secs - 32.0 * 90.0).abs() < 1e-6,
                "round 0 allocates every GPU: {}", r0.alloc_gpu_secs);
        let mut gpus_by_node: BTreeMap<usize, usize> = BTreeMap::new();
        let mut parents_by_node: BTreeMap<usize, BTreeSet<JobId>> =
            BTreeMap::new();
        for w in res.work_log.iter().filter(|w| w.round == 0) {
            assert_eq!(w.gpus, 4, "a copy takes one 4-GPU pool");
            assert!(w.pool.is_some(), "per-pool work records its pool");
            *gpus_by_node.entry(w.node).or_insert(0) += w.gpus;
            parents_by_node.entry(w.node).or_default().insert(w.parent);
        }
        assert_eq!(gpus_by_node.len(), 4, "every big node hosts copies");
        assert!(gpus_by_node.values().all(|&g| g == 8),
                "both pools of every node are booked: {gpus_by_node:?}");
        assert!(parents_by_node.values().all(|ps| ps.len() == 2),
                "each node is shared by two parents: {parents_by_node:?}");
        assert_eq!(res.sim.jct.len(), 3, "all parents complete");
    }

    #[test]
    fn big8_work_log_conserves_steps_in_both_gang_modes() {
        // §V-B conservation on the big-node preset: summed work-log steps
        // equal each parent's total, with whole-node gangs and with
        // per-pool gangs alike.
        let cluster = ClusterSpec::big8();
        for gang in [GangConfig::default(), GangConfig::shared()] {
            let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
            let res = run_with_gang(&jobs, &cluster,
                                    &EventTimeline::empty(), &cfg(), None,
                                    gang)
                .unwrap();
            let mut per_parent: BTreeMap<JobId, f64> = BTreeMap::new();
            for w in &res.work_log {
                *per_parent.entry(w.parent).or_insert(0.0) += w.steps;
            }
            for j in &jobs {
                let done = per_parent.get(&j.id).copied().unwrap_or(0.0);
                assert!((done - j.total_iters()).abs() < 1e-6,
                        "share_nodes={}: parent {} steps {} vs {}",
                        gang.share_nodes, j.id, done, j.total_iters());
            }
        }
    }

    #[test]
    fn shared_gangs_unstrand_single_type_parents_and_beat_whole_node_cru() {
        // The stranding scenario from the bugfix title: two parents that
        // each run on only one of the big nodes' two pool types. The
        // whole-node bottleneck rule (all-or-nothing) makes *every* node
        // unusable for both, so the whole-node planner strands all 32
        // GPUs; per-pool gangs hand each parent its pools, book the whole
        // cluster, and finish both jobs — so shared CRU (and GRU) beats
        // the whole-node planner's on the same scenario.
        let cluster = ClusterSpec::big8();
        let mut p0 = Job::new(0, DlModel::MiMa, 0.0, 1, 20, 100);
        p0.set_throughput(GpuType::V100, 2.0);
        let mut p1 = Job::new(1, DlModel::MiMa, 0.0, 1, 20, 100);
        p1.set_throughput(GpuType::P100, 1.5);
        let jobs = vec![p0, p1];
        let cfg = SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 50,
            horizon: 1e7,
        };
        let whole = run(&jobs, &cluster, &cfg, None);
        let shared = run_with_gang(&jobs, &cluster, &EventTimeline::empty(),
                                   &cfg, None, GangConfig::shared())
            .unwrap();
        assert!(whole.sim.jct.is_empty(),
                "whole-node gangs strand single-pool parents");
        assert_eq!(whole.sim.cru, 0.0);
        assert_eq!(shared.sim.jct.len(), 2, "both parents complete");
        assert!(shared.sim.cru > whole.sim.cru,
                "shared CRU {} !> whole-node CRU {}", shared.sim.cru,
                whole.sim.cru);
        assert!(shared.sim.cru > 0.5, "shared CRU: {}", shared.sim.cru);
        assert!(shared.sim.gru > whole.sim.gru);
        assert_eq!(shared.sim.scheduler, "hadare-shared");
        assert_eq!(whole.sim.scheduler, "hadare");
    }

    #[test]
    fn idle_node_resuming_same_parent_pays_no_restart() {
        // Regression for the restart-overhead mischarge: bindings were
        // wiped every round, so a node that idled re-paid the overhead
        // for the parent it already had loaded. Two maintenance windows
        // on the fast node force the slow node through a
        // host→idle→resume cycle of the same parent.
        use crate::cluster::events::{EventKind, EventTimeline};
        use crate::cluster::gpu::PcieGen;
        use crate::cluster::node::Node;
        let cluster = ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "k", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        );
        let mut p = Job::new(0, DlModel::Lstm, 0.0, 1, 20, 100); // 2000 it
        p.set_throughput(GpuType::V100, 2.0);
        p.set_throughput(GpuType::K80, 1.0);
        let mut events = EventTimeline::empty();
        // Fast node away rounds 1-2 and again rounds 4-5.
        events.push(90.0, EventKind::Maintenance { node: 0, duration: 180.0 });
        events.push(360.0, EventKind::Maintenance { node: 0, duration: 180.0 });
        let res = run_with_events(std::slice::from_ref(&p), &cluster,
                                  &events, &cfg(), Some(1))
            .unwrap();
        // Round 1: the K80 node loads the model for the first time — it
        // pays the 10 s overhead (80 of 90 s at 1 it/s).
        let w1: Vec<&CopyWork> =
            res.work_log.iter().filter(|w| w.round == 1).collect();
        assert_eq!(w1.len(), 1);
        assert_eq!(w1[0].node, 1);
        assert!((w1[0].steps - 80.0).abs() < 1e-9, "first load pays: {:?}",
                w1[0]);
        // Round 3: back on the V100 node; the K80 node idles but keeps
        // its loaded model.
        assert!(res.work_log.iter().any(|w| w.round == 3 && w.node == 0));
        // Round 4: the K80 node resumes the *same* parent — no second
        // overhead charge (the full 90 steps, not 80).
        let w4: Vec<&CopyWork> =
            res.work_log.iter().filter(|w| w.round == 4).collect();
        assert_eq!(w4.len(), 1);
        assert_eq!(w4[0].node, 1);
        assert!((w4[0].steps - 90.0).abs() < 1e-9,
                "idle node keeps its model loaded: {:?}", w4[0]);
        assert_eq!(res.sim.jct.len(), 1, "the job still completes");
    }
}
