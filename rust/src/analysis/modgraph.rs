//! Module-graph discovery and plan-path classification for `hadar lint`.
//!
//! The tree is discovered the way rustc does it: start at `lib.rs` (and
//! `main.rs` for the binary), parse `mod x;` declarations out of the
//! masked source, and resolve each to `x.rs` or `x/mod.rs` next to the
//! declaring file. Walking declarations instead of globbing the
//! directory means dead files that nothing mounts are *not* linted —
//! exactly the compiler's view of the crate.
//!
//! Each discovered file is classified:
//!
//! * **plan-path** — modules whose behaviour can leak into a
//!   [`crate::sched::RoundPlan`] or into solver statistics: `sched/`,
//!   `cluster/`, `jobs/`, `sim/`, `forking/`. The determinism contract
//!   (bit-identical plans at any `HADAR_PLAN_THREADS`, pinned
//!   dynamically by `prop_equivalence`/`prop_delta`) applies here, so
//!   the strictest rules do too.
//! * **harness** — everything that observes or drives the plan path
//!   without feeding it: `obs/`, `expt/`, `figures/`, `util/`, `exec/`,
//!   `runtime/`, `trace/`, the CLI, and any module with a `bench` or
//!   `tests` path segment (`sched::bench` is a harness even though it
//!   lives under `sched/`).
//!
//! `use crate::…` / inline `crate::…` paths are also collected as
//! dependency edges; they travel in the JSON report so reviewers can see
//! when a plan-path module grows a new harness dependency.

use std::collections::BTreeSet;
use std::path::Path;

use super::lexer;

/// Which rule set applies to a file (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// Can influence plans/solver stats; strict determinism rules.
    PlanPath,
    /// Observes or drives the plan path; relaxed rules.
    Harness,
}

impl FileClass {
    /// Stable lowercase name used in reports.
    pub fn as_str(self) -> &'static str {
        match self {
            FileClass::PlanPath => "plan-path",
            FileClass::Harness => "harness",
        }
    }
}

/// One discovered source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the lint root, `/`-separated (`sched/hadar.rs`).
    pub rel: String,
    /// Module path (`["sched", "hadar"]`; empty for `lib.rs`, `["main"]`
    /// for the binary root).
    pub module: Vec<String>,
    /// Rule-set classification.
    pub class: FileClass,
    /// Top-level crate modules this file references (`use crate::…` and
    /// inline `crate::…` paths), sorted and deduplicated.
    pub deps: Vec<String>,
    /// Raw source text.
    pub src: String,
}

/// The discovered crate, in deterministic (path-sorted) order.
#[derive(Debug)]
pub struct ModuleGraph {
    /// All files reachable from `lib.rs` / `main.rs`.
    pub files: Vec<SourceFile>,
}

/// Top-level modules whose files are plan-path (unless a harness
/// segment overrides).
const PLAN_PATH_ROOTS: &[&str] =
    &["sched", "cluster", "jobs", "sim", "forking"];

/// Path segments that force harness class anywhere they appear.
const HARNESS_SEGMENTS: &[&str] = &["bench", "benches", "test", "tests"];

/// Classify a module path (see module docs).
pub fn classify(module: &[String]) -> FileClass {
    if module
        .iter()
        .any(|s| HARNESS_SEGMENTS.contains(&s.as_str()))
    {
        return FileClass::Harness;
    }
    match module.first() {
        Some(first) if PLAN_PATH_ROOTS.contains(&first.as_str()) => {
            FileClass::PlanPath
        }
        _ => FileClass::Harness,
    }
}

/// Parse `mod x;` declarations (any visibility) out of masked source.
/// Inline `mod x { … }` blocks are *not* child files and are skipped.
pub fn mod_decls(masked: &str) -> Vec<String> {
    let b = masked.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(k) = masked[from..].find("mod") {
        let at = from + k;
        from = at + 3;
        if at > 0 && lexer::is_ident_byte(b[at - 1]) {
            continue;
        }
        let mut j = at + 3;
        if j >= b.len() || !b[j].is_ascii_whitespace() {
            continue;
        }
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < b.len() && lexer::is_ident_byte(b[j]) {
            j += 1;
        }
        if j == name_start {
            continue;
        }
        let name = &masked[name_start..j];
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if j < b.len() && b[j] == b';' {
            out.push(name.to_string());
        }
    }
    out
}

/// Collect the top-level targets of `crate::…` paths in masked source.
pub fn crate_deps(masked: &str) -> Vec<String> {
    let b = masked.as_bytes();
    let mut out = BTreeSet::new();
    let mut from = 0usize;
    while let Some(k) = masked[from..].find("crate::") {
        let at = from + k;
        from = at + 7;
        if at > 0 && lexer::is_ident_byte(b[at - 1]) {
            continue;
        }
        let mut j = at + 7;
        let seg_start = j;
        while j < b.len() && lexer::is_ident_byte(b[j]) {
            j += 1;
        }
        if j > seg_start {
            out.insert(masked[seg_start..j].to_string());
        }
    }
    out.into_iter().collect()
}

/// Discover the crate under `src_root` (must hold `lib.rs`; `main.rs`
/// is picked up when present). Fails on unreadable files and on `mod`
/// declarations that resolve to no file — a lint tree that silently
/// skipped files would certify nothing.
pub fn build(src_root: &Path) -> Result<ModuleGraph, String> {
    let mut files: Vec<SourceFile> = Vec::new();
    visit(src_root, "lib.rs", Vec::new(), &mut files)?;
    if src_root.join("main.rs").is_file() {
        visit(src_root, "main.rs", vec!["main".to_string()], &mut files)?;
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(ModuleGraph { files })
}

/// Load one file, record it, and recurse into its `mod` declarations.
fn visit(root: &Path, rel: &str, module: Vec<String>,
         files: &mut Vec<SourceFile>) -> Result<(), String> {
    let path = root.join(rel);
    let src = std::fs::read_to_string(&path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let masked = lexer::mask(&src);
    let decls = mod_decls(&masked.text);
    let deps = crate_deps(&masked.text);

    // Children of `a/mod.rs`, `lib.rs`, and `main.rs` live in the
    // declaring file's directory; children of `a/b.rs` live in `a/b/`.
    let file_name = rel.rsplit('/').next().unwrap_or(rel);
    let parent_dir = match rel.rfind('/') {
        Some(k) => &rel[..k],
        None => "",
    };
    let child_dir = if file_name == "lib.rs"
        || file_name == "main.rs"
        || file_name == "mod.rs"
    {
        parent_dir.to_string()
    } else {
        let stem = file_name.trim_end_matches(".rs");
        if parent_dir.is_empty() {
            stem.to_string()
        } else {
            format!("{parent_dir}/{stem}")
        }
    };

    let class = classify(&module);
    let child_prefix = module.clone();
    files.push(SourceFile {
        rel: rel.to_string(),
        class,
        module,
        deps,
        src,
    });

    for child in decls {
        let flat = if child_dir.is_empty() {
            format!("{child}.rs")
        } else {
            format!("{child_dir}/{child}.rs")
        };
        let nested = if child_dir.is_empty() {
            format!("{child}/mod.rs")
        } else {
            format!("{child_dir}/{child}/mod.rs")
        };
        let child_rel = if root.join(&flat).is_file() {
            flat
        } else if root.join(&nested).is_file() {
            nested
        } else {
            return Err(format!(
                "{rel}: `mod {child};` resolves to neither {flat} nor \
                 {nested}"
            ));
        };
        let mut child_module = child_prefix.clone();
        child_module.push(child.clone());
        visit(root, &child_rel, child_module, files)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn classification() {
        assert_eq!(classify(&m(&["sched", "hadar"])), FileClass::PlanPath);
        assert_eq!(classify(&m(&["cluster", "state"])),
                   FileClass::PlanPath);
        assert_eq!(classify(&m(&["jobs", "queue"])), FileClass::PlanPath);
        assert_eq!(classify(&m(&["sim", "engine"])), FileClass::PlanPath);
        assert_eq!(classify(&m(&["forking", "tracker"])),
                   FileClass::PlanPath);
        // Bench/test segments are harness even under plan-path roots.
        assert_eq!(classify(&m(&["sched", "bench"])), FileClass::Harness);
        assert_eq!(classify(&m(&["sched", "hadar", "tests"])),
                   FileClass::Harness);
        assert_eq!(classify(&m(&["obs", "trace"])), FileClass::Harness);
        assert_eq!(classify(&m(&["util", "rng"])), FileClass::Harness);
        assert_eq!(classify(&m(&["expt", "runner"])), FileClass::Harness);
        assert_eq!(classify(&m(&["main"])), FileClass::Harness);
        assert_eq!(classify(&m(&[])), FileClass::Harness);
    }

    #[test]
    fn mod_decl_parsing() {
        let masked = lexer::mask(
            "pub mod alloc;\nmod inner;\npub(crate) mod x;\n\
             mod tests {\n}\n// mod commented;\n",
        );
        assert_eq!(mod_decls(&masked.text),
                   vec!["alloc", "inner", "x"]);
    }

    #[test]
    fn crate_dep_parsing() {
        let masked = lexer::mask(
            "use crate::jobs::job::JobId;\n\
             let t = crate::sched::resolve_plan_threads(0);\n\
             use crate::jobs::queue::JobQueue;\n",
        );
        assert_eq!(crate_deps(&masked.text), vec!["jobs", "sched"]);
    }
}
