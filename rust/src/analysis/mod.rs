//! Static analysis for determinism and plan-path hygiene (`hadar lint`).
//!
//! The repo's core guarantee — plans and solver stats bit-identical at
//! any `HADAR_PLAN_THREADS` count, replays reproducible from a seed —
//! is defended *dynamically* by `prop_equivalence`/`prop_delta`. Three
//! past PRs each had to sweep freshly reintroduced nondeterminism
//! (`partial_cmp().unwrap()` comparators, unordered scans, ad-hoc
//! thread pools) after the property tests caught it. This subsystem
//! catches the same classes *statically*, at diff time, and CI gates on
//! it (`hadar lint --json`).
//!
//! Pipeline (all dependency-free, `std` + [`crate::util::json`] only):
//!
//! 1. [`lexer`] strips comments/strings so rules cannot flag prose, and
//!    extracts `// lint: allow(...)` suppression pragmas;
//! 2. [`modgraph`] discovers the crate from `mod` declarations (the
//!    compiler's view, not a glob) and classifies every file
//!    **plan-path** vs **harness**;
//! 3. [`rules`] runs the eight-rule engine with per-rule diagnostics,
//!    pragma suppression, and stale-pragma detection.
//!
//! [`lint_tree`] ties it together; `hadar lint [--json]` is the CLI
//! face, and `rust/tests/lint_selfaudit.rs` keeps the live tree clean
//! inside `cargo test`. The rule catalog, pragma syntax, and report
//! schema are documented in `docs/static-analysis.md`.

pub mod lexer;
pub mod modgraph;
pub mod rules;

use std::path::Path;

use crate::util::json::Json;
use rules::Finding;

/// Per-file summary carried in the report (module map + dep edges).
#[derive(Debug)]
pub struct FileSummary {
    /// Path relative to the lint root.
    pub file: String,
    /// `::`-joined module path (`sched::hadar`; `lib` for the root).
    pub module: String,
    /// `plan-path` or `harness`.
    pub class: &'static str,
    /// Top-level crate modules this file references.
    pub deps: Vec<String>,
}

/// Outcome of linting a whole tree.
#[derive(Debug)]
pub struct LintReport {
    /// Lint root, as given.
    pub root: String,
    /// Every discovered file, path-sorted.
    pub files: Vec<FileSummary>,
    /// Surviving diagnostics across all files, (file, line)-sorted.
    pub findings: Vec<Finding>,
    /// Findings suppressed by pragmas, tree-wide.
    pub suppressed: usize,
    /// Well-formed pragmas seen, tree-wide.
    pub pragmas: usize,
}

impl LintReport {
    /// `true` when nothing (violations, stale pragmas, pragma errors)
    /// was found — the state CI requires.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Number of plan-path files.
    pub fn plan_path_files(&self) -> usize {
        self.files.iter().filter(|f| f.class == "plan-path").count()
    }

    /// Human-readable report: one line per finding plus a summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{} [{}] {}\n    hint: {}\n",
                f.file, f.line, f.rule, f.message, f.suggestion
            ));
        }
        let verdict = if self.clean() { "clean" } else { "DIRTY" };
        out.push_str(&format!(
            "hadar lint: {verdict} — {} finding(s) in {} files \
             ({} plan-path; {} pragmas suppressing {} site(s))\n",
            self.findings.len(),
            self.files.len(),
            self.plan_path_files(),
            self.pragmas,
            self.suppressed,
        ));
        out
    }

    /// Machine-readable report (schema: docs/static-analysis.md).
    pub fn to_json(&self) -> Json {
        let rules = Json::Arr(
            rules::RULES
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("id", r.id)
                        .set("summary", r.summary)
                        .set(
                            "scope",
                            if r.plan_path_only {
                                "plan-path"
                            } else {
                                "all"
                            },
                        )
                        .set("in_tests", r.in_tests)
                })
                .collect(),
        );
        let modules = Json::Arr(
            self.files
                .iter()
                .map(|f| {
                    Json::obj()
                        .set("file", f.file.as_str())
                        .set("module", f.module.as_str())
                        .set("class", f.class)
                        .set(
                            "deps",
                            Json::Arr(
                                f.deps
                                    .iter()
                                    .map(|d| Json::Str(d.clone()))
                                    .collect(),
                            ),
                        )
                })
                .collect(),
        );
        let findings = Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    Json::obj()
                        .set("rule", f.rule.as_str())
                        .set("file", f.file.as_str())
                        .set("line", f.line)
                        .set("class", f.class)
                        .set("message", f.message.as_str())
                        .set("suggestion", f.suggestion.as_str())
                })
                .collect(),
        );
        Json::obj()
            .set("tool", "hadar-lint")
            .set("version", 1u64)
            .set("root", self.root.as_str())
            .set("rules", rules)
            .set("modules", modules)
            .set("findings", findings)
            .set(
                "summary",
                Json::obj()
                    .set("files", self.files.len())
                    .set("plan_path_files", self.plan_path_files())
                    .set("findings", self.findings.len())
                    .set("pragmas", self.pragmas)
                    .set("suppressed", self.suppressed)
                    .set("clean", self.clean()),
            )
    }
}

/// Lint the crate rooted at `src_root` (the directory holding
/// `lib.rs`). Fails only on infrastructure problems (unreadable files,
/// unresolvable `mod` declarations) — findings are data, not errors.
pub fn lint_tree(src_root: &Path) -> Result<LintReport, String> {
    let graph = modgraph::build(src_root)?;
    let mut report = LintReport {
        root: src_root.display().to_string(),
        files: Vec::new(),
        findings: Vec::new(),
        suppressed: 0,
        pragmas: 0,
    };
    for sf in &graph.files {
        let fl = rules::lint_file(sf);
        report.suppressed += fl.suppressed;
        report.pragmas += fl.pragmas;
        report.findings.extend(fl.findings);
        report.files.push(FileSummary {
            file: sf.rel.clone(),
            module: if sf.module.is_empty() {
                "lib".to_string()
            } else {
                sf.module.join("::")
            },
            class: sf.class.as_str(),
            deps: sf.deps.clone(),
        });
    }
    report
        .findings
        .sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule.as_str())
                .cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
        });
    Ok(report)
}
