//! The `hadar lint` rule engine: eight determinism/plan-path rules, a
//! suppression-pragma layer, and stale-pragma detection.
//!
//! Every rule encodes an invariant the property tests
//! (`prop_equivalence`, `prop_delta`) defend *dynamically* — plans and
//! solver stats bit-identical at any `HADAR_PLAN_THREADS` count, replays
//! reproducible from a seed — so violations are caught at diff time
//! instead of at property-test time. Rules scan the masked text
//! ([`crate::analysis::lexer::mask`]), so comments and string literals
//! can mention any forbidden token freely.
//!
//! The catalog, with rationale per rule, lives in
//! `docs/static-analysis.md`. Suppression uses
//! `// lint: allow(<rule>, reason = "...")` pragmas (line scope) or
//! `allow-file(...)` (file scope); a pragma that suppresses nothing is
//! itself reported (`stale-pragma`), as is one that does not parse or
//! names an unknown rule (`pragma-syntax`).

use std::collections::BTreeSet;

use super::lexer::{self, Masked};
use super::modgraph::{FileClass, SourceFile};

/// Static description of one rule.
#[derive(Debug)]
pub struct Rule {
    /// Stable kebab-case id (used in pragmas and reports).
    pub id: &'static str,
    /// One-line statement of the invariant.
    pub summary: &'static str,
    /// `true`: only plan-path files are checked.
    pub plan_path_only: bool,
    /// `true`: `#[cfg(test)] mod … { }` blocks are checked too.
    pub in_tests: bool,
    /// What to do instead (rendered as the finding's hint).
    pub suggestion: &'static str,
}

/// The rule catalog. Ids are load-bearing: pragmas and fixture tests
/// reference them, and `docs/static-analysis.md` documents them 1:1.
pub const RULES: &[Rule] = &[
    Rule {
        id: "float-total-cmp",
        summary: "float comparisons must use total_cmp, never \
                  partial_cmp",
        plan_path_only: false,
        in_tests: true,
        suggestion: "sort/compare floats with f64::total_cmp — \
                     partial_cmp().unwrap() panics on NaN and its \
                     Option detour invites order-unstable fallbacks \
                     (PR 3/4 swept these once already)",
    },
    Rule {
        id: "unordered-iteration",
        summary: "no HashMap/HashSet iteration in plan-path modules \
                  (keyed probes are fine)",
        plan_path_only: true,
        in_tests: false,
        suggestion: "iterate a BTreeMap/BTreeSet instead, or keep the \
                     hash container strictly keyed (get/insert/remove) \
                     — hash iteration order can differ across runs and \
                     leak into plans",
    },
    Rule {
        id: "wall-clock",
        summary: "no Instant::now/SystemTime::now outside obs:: and \
                  util::log",
        plan_path_only: false,
        in_tests: false,
        suggestion: "route timing through obs:: spans/metrics; a \
                     harness timer that never feeds a plan may carry a \
                     `// lint: allow(wall-clock, reason = ...)` pragma",
    },
    Rule {
        id: "raw-thread",
        summary: "thread::spawn/scope must size workers via \
                  sched::resolve_plan_threads",
        plan_path_only: false,
        in_tests: false,
        suggestion: "take the worker count from \
                     sched::resolve_plan_threads (the \
                     HADAR_PLAN_THREADS knob) — ad-hoc pools are how \
                     thread-count-dependent plans sneak in; the \
                     enclosing fn must call it or accept a `threads` \
                     parameter",
    },
    Rule {
        id: "deprecated-shim",
        summary: "no #[deprecated] forwarding shims",
        plan_path_only: false,
        in_tests: true,
        suggestion: "repoint the callers and delete the shim — \
                     deprecated forwarding lives at most one PR (the \
                     PR 9 resolve_plan_threads shim is the cautionary \
                     example)",
    },
    Rule {
        id: "no-unsafe",
        summary: "no unsafe code",
        plan_path_only: false,
        in_tests: true,
        suggestion: "rewrite with safe std primitives; the crate is \
                     dependency-free safe Rust throughout and the \
                     solvers get their speed from algorithmic work, \
                     not unsafe",
    },
    Rule {
        id: "nondet-rng",
        summary: "no thread_rng/from_entropy/RandomState entropy \
                  sources",
        plan_path_only: false,
        in_tests: true,
        suggestion: "use util::rng::Rng (seeded, forkable) so every \
                     trace, sweep, and property case replays from its \
                     seed",
    },
    Rule {
        id: "env-read",
        summary: "no std::env reads outside the config layer",
        plan_path_only: false,
        in_tests: false,
        suggestion: "read the environment once at construction/config \
                     time (resolve_plan_threads is the pattern) and \
                     pass the value down — mid-round env reads make \
                     behaviour depend on when a round runs",
    },
];

/// Look up a rule by id.
pub fn rule(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// One diagnostic: a rule violation, a stale pragma, or a pragma
/// syntax error.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule id (`stale-pragma`/`pragma-syntax` for engine diagnostics).
    pub rule: String,
    /// File, relative to the lint root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Classification of the file (`plan-path`/`harness`).
    pub class: &'static str,
    /// What was found.
    pub message: String,
    /// What to do about it.
    pub suggestion: String,
}

/// Lint outcome for one file.
#[derive(Debug, Default)]
pub struct FileLint {
    /// Surviving diagnostics (post-suppression), line-sorted.
    pub findings: Vec<Finding>,
    /// Findings suppressed by pragmas.
    pub suppressed: usize,
    /// Pragmas seen (well-formed).
    pub pragmas: usize,
}

/// Run every applicable rule over one file (see module docs).
pub fn lint_file(sf: &SourceFile) -> FileLint {
    let m = lexer::mask(&sf.src);
    let tests = test_ranges(&m.text);
    let fns = fn_spans(&m.text);

    // (byte offset, rule, message) before suppression.
    let mut raw: Vec<(usize, &'static Rule, String)> = Vec::new();
    for r in RULES {
        if r.plan_path_only && sf.class != FileClass::PlanPath {
            continue;
        }
        let sites: Vec<(usize, String)> = match r.id {
            "float-total-cmp" => ident_sites(&m.text, "partial_cmp")
                .into_iter()
                .map(|at| (at, "partial_cmp on the \
                                comparison path".to_string()))
                .collect(),
            "unordered-iteration" => unordered_iteration(&m.text),
            "wall-clock" => wall_clock(sf, &m.text),
            "raw-thread" => raw_thread(&m.text, &fns),
            "deprecated-shim" => substr_sites(&m.text, "#[deprecated")
                .into_iter()
                .map(|at| (at, "#[deprecated] forwarding \
                                shim".to_string()))
                .collect(),
            "no-unsafe" => ident_sites(&m.text, "unsafe")
                .into_iter()
                .map(|at| (at, "unsafe block/impl/fn".to_string()))
                .collect(),
            "nondet-rng" => nondet_rng(&m.text),
            "env-read" => env_read(&m.text),
            _ => Vec::new(),
        };
        for (at, msg) in sites {
            if !r.in_tests && in_ranges(&tests, at) {
                continue;
            }
            raw.push((at, r, msg));
        }
    }

    // Suppression: first covering pragma wins and is marked used.
    let mut used = vec![0usize; m.pragmas.len()];
    let mut out = FileLint {
        pragmas: m.pragmas.len(),
        ..FileLint::default()
    };
    for (at, r, msg) in raw {
        let line = m.line_of(at);
        let hit = m.pragmas.iter().enumerate().find(|(_, p)| {
            p.rule == r.id
                && (p.file_level
                    || (p.trailing && p.line == line)
                    || (!p.trailing
                        && m.next_code_line(p.line + 1) == Some(line)))
        });
        match hit {
            Some((pi, _)) => {
                used[pi] += 1;
                out.suppressed += 1;
            }
            None => out.findings.push(Finding {
                rule: r.id.to_string(),
                file: sf.rel.clone(),
                line,
                class: sf.class.as_str(),
                message: msg,
                suggestion: r.suggestion.to_string(),
            }),
        }
    }

    // Engine diagnostics: malformed, unknown-rule, and stale pragmas.
    for e in &m.errors {
        out.findings.push(Finding {
            rule: "pragma-syntax".to_string(),
            file: sf.rel.clone(),
            line: e.line,
            class: sf.class.as_str(),
            message: format!("malformed lint pragma: {}", e.msg),
            suggestion: "write `// lint: allow(<rule>, reason = \
                         \"...\")` or `allow-file(...)`"
                .to_string(),
        });
    }
    for (pi, p) in m.pragmas.iter().enumerate() {
        if rule(&p.rule).is_none() {
            out.findings.push(Finding {
                rule: "pragma-syntax".to_string(),
                file: sf.rel.clone(),
                line: p.line,
                class: sf.class.as_str(),
                message: format!("pragma names unknown rule `{}`",
                                 p.rule),
                suggestion: "rule ids are listed in \
                             docs/static-analysis.md"
                    .to_string(),
            });
        } else if used[pi] == 0 {
            out.findings.push(Finding {
                rule: "stale-pragma".to_string(),
                file: sf.rel.clone(),
                line: p.line,
                class: sf.class.as_str(),
                message: format!(
                    "allow({}) suppresses nothing (reason was: {})",
                    p.rule, p.reason
                ),
                suggestion: "the violation it covered is gone — \
                             delete the pragma"
                    .to_string(),
            });
        }
    }

    out.findings.sort_by(|a, b| {
        (a.line, a.rule.as_str()).cmp(&(b.line, b.rule.as_str()))
    });
    out
}

// ------------------------------------------------------------- scanning

/// Byte offsets of `word` as a standalone identifier.
fn ident_sites(text: &str, word: &str) -> Vec<usize> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(k) = text[from..].find(word) {
        let at = from + k;
        from = at + word.len();
        let pre_ok = at == 0 || !lexer::is_ident_byte(b[at - 1]);
        let end = at + word.len();
        let post_ok = end >= b.len() || !lexer::is_ident_byte(b[end]);
        if pre_ok && post_ok {
            out.push(at);
        }
    }
    out
}

/// Byte offsets of a path-like pattern (e.g. `thread::spawn`): the
/// leading segment must start on an identifier boundary; with
/// `prefix = false` the trailing end must sit on one too.
fn path_sites_with(text: &str, pat: &str, prefix: bool) -> Vec<usize> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(k) = text[from..].find(pat) {
        let at = from + k;
        from = at + pat.len();
        let pre_ok = at == 0 || !lexer::is_ident_byte(b[at - 1]);
        let end = at + pat.len();
        let post_ok =
            prefix || end >= b.len() || !lexer::is_ident_byte(b[end]);
        if pre_ok && post_ok {
            out.push(at);
        }
    }
    out
}

/// [`path_sites_with`] requiring both boundaries.
fn path_sites(text: &str, pat: &str) -> Vec<usize> {
    path_sites_with(text, pat, false)
}

/// Raw substring offsets (for non-identifier patterns).
fn substr_sites(text: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(k) = text[from..].find(pat) {
        out.push(from + k);
        from = from + k + pat.len();
    }
    out
}

/// Is `at` inside any of the half-open byte ranges?
fn in_ranges(ranges: &[(usize, usize)], at: usize) -> bool {
    ranges.iter().any(|&(lo, hi)| at >= lo && at < hi)
}

/// Byte ranges of `#[cfg(test)]`-gated `mod`/`fn` items (masked text).
fn test_ranges(text: &str) -> Vec<(usize, usize)> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    for at in substr_sites(text, "#[cfg(test)]") {
        let mut j = at + "#[cfg(test)]".len();
        // Skip whitespace and further attributes.
        loop {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < b.len() && b[j] == b'#' {
                while j < b.len() && b[j] != b']' {
                    j += 1;
                }
                j += 1;
                continue;
            }
            break;
        }
        // The gated item must be a mod/fn to carve a range out; other
        // items (consts, uses) carry no lintable body of their own.
        let rest = &text[j..];
        let is_item = rest.starts_with("mod ")
            || rest.starts_with("pub mod ")
            || rest.starts_with("fn ")
            || rest.starts_with("pub fn ")
            || rest.starts_with("pub(crate) mod ")
            || rest.starts_with("pub(crate) fn ");
        if !is_item {
            continue;
        }
        if let Some(open) = text[j..].find('{') {
            let open = j + open;
            if let Some(close) = match_brace(b, open) {
                out.push((at, close));
            }
        }
    }
    out
}

/// Offset just past the `}` matching the `{` at `open` (masked text, so
/// braces in strings/comments are already gone).
fn match_brace(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    let mut j = open;
    while j < b.len() {
        match b[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(j + 1);
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// One `fn` item's signature + body byte span.
struct FnSpan {
    sig_start: usize,
    body_start: usize,
    body_end: usize,
}

/// All `fn` spans in the file (masked text), including nested fns.
fn fn_spans(text: &str) -> Vec<FnSpan> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    for at in ident_sites(text, "fn") {
        // Body opens at the first `{`; a `;` first means a bodiless
        // trait/extern declaration.
        let mut j = at;
        while j < b.len() && b[j] != b'{' && b[j] != b';' {
            j += 1;
        }
        if j >= b.len() || b[j] == b';' {
            continue;
        }
        if let Some(end) = match_brace(b, j) {
            out.push(FnSpan {
                sig_start: at,
                body_start: j,
                body_end: end,
            });
        }
    }
    out
}

/// The innermost `fn` span containing `at`.
fn enclosing_fn<'a>(fns: &'a [FnSpan], at: usize) -> Option<&'a FnSpan> {
    fns.iter()
        .filter(|f| at >= f.sig_start && at < f.body_end)
        .max_by_key(|f| f.sig_start)
}

// ------------------------------------------------------------ the rules

/// `wall-clock`: `Instant::now`/`SystemTime::now` anywhere but the
/// sanctioned timer homes (`obs::*`, `util::log`).
fn wall_clock(sf: &SourceFile, text: &str) -> Vec<(usize, String)> {
    let exempt = sf.module.first().map(String::as_str) == Some("obs")
        || sf.module == ["util".to_string(), "log".to_string()];
    if exempt {
        return Vec::new();
    }
    let mut out = Vec::new();
    for pat in ["Instant::now", "SystemTime::now"] {
        for at in path_sites(text, pat) {
            out.push((at, format!("{pat} outside obs::/util::log")));
        }
    }
    out.sort_by_key(|&(at, _)| at);
    out
}

/// `raw-thread`: a `thread::spawn`/`thread::scope` whose enclosing fn
/// neither calls `resolve_plan_threads` nor receives a `threads`
/// parameter in its signature.
fn raw_thread(text: &str, fns: &[FnSpan]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for pat in ["thread::spawn", "thread::scope"] {
        for at in path_sites(text, pat) {
            let justified = match enclosing_fn(fns, at) {
                Some(f) => {
                    let sig = &text[f.sig_start..f.body_start];
                    let body = &text[f.body_start..f.body_end];
                    !ident_sites(sig, "threads").is_empty()
                        || !ident_sites(body, "resolve_plan_threads")
                            .is_empty()
                }
                None => false,
            };
            if !justified {
                out.push((at, format!(
                    "{pat} with a worker count not tied to \
                     resolve_plan_threads"
                )));
            }
        }
    }
    out.sort_by_key(|&(at, _)| at);
    out
}

/// `nondet-rng`: ambient entropy sources.
fn nondet_rng(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for word in ["thread_rng", "from_entropy", "RandomState"] {
        for at in ident_sites(text, word) {
            out.push((at, format!("nondeterministic entropy source \
                                   `{word}`")));
        }
    }
    for at in path_sites(text, "rand::random") {
        out.push((at, "nondeterministic entropy source \
                       `rand::random`".to_string()));
    }
    out.sort_by_key(|&(at, _)| at);
    out
}

/// `env-read`: any `std::env::var*`/`env::vars*` read.
fn env_read(text: &str) -> Vec<(usize, String)> {
    path_sites_with(text, "env::var", true)
        .into_iter()
        .map(|at| (at, "environment read outside the config \
                        layer".to_string()))
        .collect()
}

/// `unordered-iteration`: iteration over identifiers bound to
/// `HashMap`/`HashSet` in this file. Bindings are recognised from
/// `name: HashMap<…>` (fields, params, typed lets) and
/// `name = HashMap::new()`-style initialisers; iteration is
/// `.iter()/.keys()/.values()/.drain()/.retain()/…` on such a name, or
/// a `for … in name` loop. Keyed probes (`get`/`insert`/`remove`/…)
/// never flag.
fn unordered_iteration(text: &str) -> Vec<(usize, String)> {
    let b = text.as_bytes();
    let mut names: BTreeSet<String> = BTreeSet::new();
    for ty in ["HashMap", "HashSet"] {
        for at in ident_sites(text, ty) {
            if let Some(name) = binding_name_before(text, at) {
                names.insert(name);
            }
        }
    }
    const ITER_METHODS: &[&str] = &[
        "iter", "iter_mut", "keys", "values", "values_mut",
        "into_iter", "into_keys", "into_values", "drain", "retain",
    ];
    let mut out = Vec::new();
    for name in &names {
        for at in ident_sites(text, name) {
            let end = at + name.len();
            if let Some(meth) = dot_method_after(text, end) {
                if ITER_METHODS.contains(&meth.as_str()) {
                    out.push((at, format!(
                        "hash-order iteration `{name}.{meth}()` \
                         (container is a HashMap/HashSet)"
                    )));
                }
                continue;
            }
            if for_in_before(b, at) {
                out.push((at, format!(
                    "hash-order iteration `for … in {name}`"
                )));
            }
        }
    }
    out.sort_by_key(|&(at, _)| at);
    out
}

/// Walk back from a `HashMap`/`HashSet` token to the identifier it is
/// bound to, across `name: [&][mut] Hash…` and `name = Hash…` shapes
/// (newlines included — declarations wrap at 80 cols here).
fn binding_name_before(text: &str, at: usize) -> Option<String> {
    let b = text.as_bytes();
    let mut j = at;
    let skip_ws = |j: &mut usize| {
        while *j > 0 && b[*j - 1].is_ascii_whitespace() {
            *j -= 1;
        }
    };
    skip_ws(&mut j);
    // Optional `mut`, optional reference sigils.
    if j >= 3 && &b[j - 3..j] == b"mut" {
        j -= 3;
        skip_ws(&mut j);
    }
    while j > 0 && b[j - 1] == b'&' {
        j -= 1;
        skip_ws(&mut j);
    }
    if j == 0 {
        return None;
    }
    let sep = b[j - 1];
    if sep != b':' && sep != b'=' {
        return None;
    }
    j -= 1;
    // `::HashMap` is a path, not a binding; `==` is a comparison.
    if j > 0 && (b[j - 1] == b':' || b[j - 1] == b'=') {
        return None;
    }
    skip_ws(&mut j);
    let end = j;
    while j > 0 && lexer::is_ident_byte(b[j - 1]) {
        j -= 1;
    }
    if j == end {
        return None;
    }
    let name = text.get(j..end)?;
    if name.as_bytes()[0].is_ascii_digit() {
        return None;
    }
    Some(name.to_string())
}

/// The `.method` chained right after byte `end`, if any.
fn dot_method_after(text: &str, end: usize) -> Option<String> {
    let b = text.as_bytes();
    let mut j = end;
    while j < b.len() && b[j].is_ascii_whitespace() {
        j += 1;
    }
    if j >= b.len() || b[j] != b'.' {
        return None;
    }
    j += 1;
    while j < b.len() && b[j].is_ascii_whitespace() {
        j += 1;
    }
    let start = j;
    while j < b.len() && lexer::is_ident_byte(b[j]) {
        j += 1;
    }
    if j == start {
        return None;
    }
    Some(text[start..j].to_string())
}

/// Is the identifier at `at` the sequence of a `for … in [&][mut]` loop?
fn for_in_before(b: &[u8], at: usize) -> bool {
    let mut j = at;
    let skip_ws = |j: &mut usize| {
        while *j > 0 && b[*j - 1].is_ascii_whitespace() {
            *j -= 1;
        }
    };
    skip_ws(&mut j);
    if j >= 3 && &b[j - 3..j] == b"mut" {
        j -= 3;
        skip_ws(&mut j);
    }
    while j > 0 && b[j - 1] == b'&' {
        j -= 1;
        skip_ws(&mut j);
    }
    j >= 2
        && &b[j - 2..j] == b"in"
        && (j == 2 || !lexer::is_ident_byte(b[j - 3]))
}
