//! Comment/string stripping and pragma extraction for `hadar lint`.
//!
//! The rule engine ([`crate::analysis::rules`]) scans for tokens like
//! `partial_cmp` or `Instant::now`. Matching those against raw source
//! would flag the *documentation* of past bugs (e.g. the NaN-comparator
//! regression notes in `util/stats.rs` and `sched/hadar.rs`), so every
//! file first passes through [`mask`]: comments, string literals, and
//! char literals are replaced byte-for-byte with spaces while newlines
//! are kept, leaving a same-length text where byte offsets and line
//! numbers still agree with the original file.
//!
//! Suppression pragmas live in ordinary `//` comments and are collected
//! during the same pass (masking would otherwise erase them):
//!
//! ```text
//! // lint: allow(wall-clock, reason = "bench timing, not plan input")
//! // lint: allow-file(wall-clock, reason = "every row here is timed")
//! ```
//!
//! A standalone pragma comment covers the next code line; a pragma
//! trailing code on the same line covers that line; `allow-file` covers
//! the whole file. The `reason` is mandatory — a pragma without one is
//! reported as a `pragma-syntax` finding, and a pragma that suppresses
//! nothing is reported as `stale-pragma` (see the rule engine).

/// A parsed lint-suppression pragma.
#[derive(Clone, Debug, PartialEq)]
pub struct Pragma {
    /// 1-based line of the pragma comment.
    pub line: usize,
    /// `true` for `allow-file(...)`: suppresses the rule in the whole
    /// file. `false` for line-scoped `allow(...)`.
    pub file_level: bool,
    /// `true` when code precedes the comment on its line (the pragma
    /// then covers that line); `false` for a standalone comment line
    /// (covers the next code line).
    pub trailing: bool,
    /// Rule id being suppressed (validated by the rule engine).
    pub rule: String,
    /// Mandatory human justification.
    pub reason: String,
}

/// A comment that announces itself as a pragma (`// lint: ...`) but does
/// not parse — wrong shape, unknown verb, or a missing/empty reason.
#[derive(Clone, Debug, PartialEq)]
pub struct PragmaError {
    /// 1-based line of the malformed pragma.
    pub line: usize,
    /// What is wrong with it.
    pub msg: String,
}

/// The masked view of one source file (see module docs).
#[derive(Debug)]
pub struct Masked {
    /// Same byte length as the input; comments, strings, and char
    /// literals are spaces, newlines survive.
    pub text: String,
    /// Well-formed suppression pragmas, in file order.
    pub pragmas: Vec<Pragma>,
    /// Malformed pragmas, in file order.
    pub errors: Vec<PragmaError>,
    /// Byte offset of each line start; index `k` is line `k + 1`.
    line_starts: Vec<usize>,
}

impl Masked {
    /// 1-based line containing byte offset `at`.
    pub fn line_of(&self, at: usize) -> usize {
        match self.line_starts.binary_search(&at) {
            Ok(k) => k + 1,
            Err(k) => k,
        }
    }

    /// 1-based number of the first line at or after line `from` (1-based)
    /// that carries any masked (i.e. code) content, or `None` when the
    /// rest of the file is comments/blank. Standalone pragmas use this to
    /// find the line they cover.
    pub fn next_code_line(&self, from: usize) -> Option<usize> {
        let bytes = self.text.as_bytes();
        for k in from.saturating_sub(1)..self.line_starts.len() {
            let start = self.line_starts[k];
            let end = self
                .line_starts
                .get(k + 1)
                .copied()
                .unwrap_or(bytes.len());
            if self.text[start..end].trim().is_empty() {
                continue;
            }
            return Some(k + 1);
        }
        None
    }
}

/// Is `c` an identifier byte (`[A-Za-z0-9_]`)?
pub fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Strip comments, strings, and char literals from `src` (see module
/// docs), collecting pragmas on the way.
pub fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut line_starts = vec![0usize];
    for (i, &c) in b.iter().enumerate() {
        if c == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let line_of = |at: usize| -> usize {
        match line_starts.binary_search(&at) {
            Ok(k) => k + 1,
            Err(k) => k,
        }
    };
    let blank = |out: &mut [u8], lo: usize, hi: usize| {
        for c in out[lo..hi].iter_mut() {
            if *c != b'\n' && *c != b'\r' {
                *c = b' ';
            }
        }
    };

    let mut pragmas = Vec::new();
    let mut errors = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        // Line comment (incl. doc comments) — possibly a pragma.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let end = src[i..]
                .find('\n')
                .map(|k| i + k)
                .unwrap_or(b.len());
            let line = line_of(i);
            let start = line_starts[line - 1];
            let trailing =
                !src[start..i].trim().is_empty();
            match parse_pragma(&src[i..end]) {
                PragmaParse::Ok(rule, file_level, reason) => {
                    pragmas.push(Pragma {
                        line,
                        file_level,
                        trailing,
                        rule,
                        reason,
                    });
                }
                PragmaParse::Bad(msg) => {
                    errors.push(PragmaError { line, msg });
                }
                PragmaParse::NotAPragma => {}
            }
            blank(&mut out, i, end);
            i = end;
            continue;
        }
        // Block comment, nested per Rust.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*'
                    && j + 1 < b.len()
                    && b[j + 1] == b'/'
                {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Raw / byte / raw-byte strings: r"", r#""#, b"", br#""#.
        if (c == b'r' || c == b'b')
            && (i == 0 || !is_ident_byte(b[i - 1]))
        {
            if let Some(j) = raw_or_byte_string_end(b, i) {
                blank(&mut out, i, j);
                i = j;
                continue;
            }
        }
        // Ordinary string literal.
        if c == b'"' {
            let j = string_end(b, i);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if let Some(j) = char_literal_end(b, i) {
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            // Lifetime: skip the quote and its identifier unmasked.
            i += 1;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            continue;
        }
        i += 1;
    }

    Masked {
        text: String::from_utf8(out)
            .expect("masking only rewrites bytes to ASCII spaces"),
        pragmas,
        errors,
        line_starts,
    }
}

/// End (exclusive) of the `"..."` literal starting at `i`.
fn string_end(b: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    b.len()
}

/// End of a raw/byte/raw-byte string starting at `i` (`r`/`b` seen), or
/// `None` when `i` does not actually start one.
fn raw_or_byte_string_end(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    // `br` / `rb` prefixes: at most one more prefix byte.
    if j < b.len()
        && (b[j] == b'r' || b[j] == b'b')
        && b[i] != b[j]
    {
        j += 1;
    }
    let raw = b[i..j].contains(&b'r');
    if !raw {
        // Plain byte string `b"..."`.
        return if j < b.len() && b[j] == b'"' {
            Some(string_end(b, j))
        } else {
            None
        };
    }
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && k < b.len() && b[k] == b'#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(b.len())
}

/// End of the char literal at `i` (a `'` seen), or `None` when the quote
/// starts a lifetime instead.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    let next = *b.get(i + 1)?;
    if next == b'\\' {
        // Scan from the backslash itself so `\\` and `\'` consume
        // their escaped byte before the closing quote is looked for
        // (mirrors [`string_end`]).
        let mut j = i + 1;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'\'' => return Some(j + 1),
                _ => j += 1,
            }
        }
        return Some(b.len());
    }
    // `'a'` is a char; `'a` (no closing quote after one ident char run)
    // is a lifetime. Multi-byte scalars (`'∂'`) fall to the scan below.
    if is_ident_byte(next) {
        let mut j = i + 1;
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        return if j < b.len() && b[j] == b'\'' {
            Some(j + 1)
        } else {
            None
        };
    }
    if next == b'\'' {
        // `''` cannot happen in valid Rust; treat as empty literal.
        return Some(i + 2);
    }
    // Non-identifier scalar: scan to the closing quote on this line.
    let mut j = i + 1;
    while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
        j += 1;
    }
    if j < b.len() && b[j] == b'\'' {
        Some(j + 1)
    } else {
        None
    }
}

enum PragmaParse {
    Ok(String, bool, String),
    Bad(String),
    NotAPragma,
}

/// Parse one `//...` comment as a pragma. Doc comments (`///`, `//!`)
/// never count; anything starting `lint:` must parse fully or is an
/// error.
fn parse_pragma(comment: &str) -> PragmaParse {
    let body = &comment[2..];
    if body.starts_with('/') || body.starts_with('!') {
        return PragmaParse::NotAPragma;
    }
    let body = body.trim();
    let Some(rest) = body.strip_prefix("lint:") else {
        return PragmaParse::NotAPragma;
    };
    let rest = rest.trim();
    let (file_level, rest) =
        if let Some(r) = rest.strip_prefix("allow-file(") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("allow(") {
            (false, r)
        } else {
            return PragmaParse::Bad(format!(
                "expected `allow(<rule>, reason = \"...\")` or \
                 `allow-file(...)`, got `{rest}`"
            ));
        };
    let Some(rest) = rest.strip_suffix(')') else {
        return PragmaParse::Bad("missing closing `)`".to_string());
    };
    let Some((rule, reason_part)) = rest.split_once(',') else {
        return PragmaParse::Bad(
            "missing `, reason = \"...\"` after the rule id".to_string(),
        );
    };
    let rule = rule.trim();
    if rule.is_empty() {
        return PragmaParse::Bad("empty rule id".to_string());
    }
    let reason_part = reason_part.trim();
    let Some(q) = reason_part.strip_prefix("reason") else {
        return PragmaParse::Bad(
            "expected `reason = \"...\"`".to_string(),
        );
    };
    let Some(q) = q.trim_start().strip_prefix('=') else {
        return PragmaParse::Bad(
            "expected `=` after `reason`".to_string(),
        );
    };
    let q = q.trim();
    let reason = q
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or("");
    if reason.trim().is_empty() {
        return PragmaParse::Bad(
            "reason must be a non-empty quoted string".to_string(),
        );
    }
    PragmaParse::Ok(rule.to_string(), file_level, reason.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let m = mask("let x = 1; // partial_cmp here\n/* and\nhere */y");
        assert!(!m.text.contains("partial_cmp"));
        assert!(!m.text.contains("here"));
        assert!(m.text.contains("let x = 1;"));
        assert!(m.text.contains('y'));
        assert_eq!(m.text.len(), 46);
        assert_eq!(m.text.matches('\n').count(), 2);
    }

    #[test]
    fn strips_nested_block_comments() {
        let m = mask("a /* one /* two */ still */ b");
        assert!(m.text.contains('a'));
        assert!(m.text.contains('b'));
        assert!(!m.text.contains("still"));
    }

    #[test]
    fn strips_strings_and_escapes() {
        let m = mask(r#"let s = "Instant::now \" quoted"; t"#);
        assert!(!m.text.contains("Instant"));
        assert!(m.text.contains("let s ="));
        assert!(m.text.ends_with("; t"));
    }

    #[test]
    fn strips_raw_and_byte_strings() {
        let m = mask(r##"a r#"thread_rng "#; b"env::var"; r"x"; c"##);
        assert!(!m.text.contains("thread_rng"));
        assert!(!m.text.contains("env::var"));
        assert!(m.text.contains('a'));
        assert!(m.text.contains('c'));
    }

    #[test]
    fn char_literals_masked_lifetimes_kept() {
        let m = mask("fn f<'a>(x: &'a str) { let c = 'u'; let d = '\\n'; }");
        assert!(m.text.contains("<'a>"));
        assert!(m.text.contains("&'a str"));
        assert!(!m.text.contains("'u'"));
        assert!(!m.text.contains("\\n"));
    }

    #[test]
    fn escaped_backslash_and_quote_char_literals_end_correctly() {
        // Regression: `'\\'` must not eat its own closing quote and
        // mask everything to the next stray `"`/`'` in the file.
        let m = mask("let a = '\\\\'; let keep = 1; let b = '\\''; tail");
        assert!(m.text.contains("let keep = 1;"), "{}", m.text);
        assert!(m.text.ends_with("tail"), "{}", m.text);
        assert!(!m.text.contains('\\'));
    }

    #[test]
    fn pragma_line_and_file_level() {
        let src = "\
// lint: allow-file(wall-clock, reason = \"bench module\")
let a = 1; // lint: allow(env-read, reason = \"config knob\")
// lint: allow(no-unsafe, reason = \"ffi\")
let b = 2;
";
        let m = mask(src);
        assert_eq!(m.errors.len(), 0, "{:?}", m.errors);
        assert_eq!(m.pragmas.len(), 3);
        assert!(m.pragmas[0].file_level);
        assert!(!m.pragmas[0].trailing);
        assert_eq!(m.pragmas[0].rule, "wall-clock");
        assert!(m.pragmas[1].trailing);
        assert_eq!(m.pragmas[1].line, 2);
        assert_eq!(m.pragmas[2].line, 3);
        assert!(!m.pragmas[2].trailing);
        // Standalone pragma on line 3 covers the code on line 4.
        assert_eq!(m.next_code_line(4), Some(4));
    }

    #[test]
    fn malformed_pragmas_are_errors() {
        let cases = [
            "// lint: allow(wall-clock)",
            "// lint: allow(wall-clock, reason = \"\")",
            "// lint: deny(wall-clock, reason = \"x\")",
            "// lint: allow(, reason = \"x\")",
            "// lint: allow(wall-clock, reason = \"x\"",
        ];
        for c in cases {
            let m = mask(c);
            assert_eq!(m.pragmas.len(), 0, "{c}");
            assert_eq!(m.errors.len(), 1, "{c}");
        }
        // Doc comments and strings never parse as pragmas.
        let m = mask("/// lint: allow(x, reason = \"y\")\nlet s = \"lint: allow(x, reason = \\\"y\\\")\";");
        assert!(m.pragmas.is_empty() && m.errors.is_empty());
    }

    #[test]
    fn line_of_and_next_code_line() {
        let m = mask("a\n\n// c\nb\n");
        assert_eq!(m.line_of(0), 1);
        assert_eq!(m.line_of(2), 2);
        assert_eq!(m.next_code_line(2), Some(4));
        assert_eq!(m.next_code_line(5), None);
    }
}
