//! Streaming-scale HadarE guarantees, engine-in-the-loop:
//!
//! * **Thread-count determinism** — the sharded planner (gang-matrix
//!   build + candidate sort split across a worker pool) must produce
//!   **bit-identical** `RoundPlan`s and `SimResult`s at 1, 2, and 8
//!   workers, mirroring the expt worker-count determinism contract. The
//!   thread count is a latency knob, never a semantics knob.
//! * **Churn safety** — warm carry-over bindings that reference nodes
//!   removed by maintenance drains are dropped cleanly: no stale
//!   placements, the row cache invalidates, and the engine charges the
//!   restart overhead exactly once per rebind.

use hadar::cluster::events::{EventKind, EventTimeline};
use hadar::cluster::gpu::{GpuType, PcieGen};
use hadar::cluster::node::Node;
use hadar::cluster::spec::ClusterSpec;
use hadar::forking::forker::ForkIds;
use hadar::forking::tracker::JobTracker;
use hadar::jobs::job::{Job, JobId};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::hadare::{GangConfig, HadarE, PrevRound};
use hadar::sched::{RoundCtx, RoundPlan};
use hadar::sim::engine::SimConfig;
use hadar::sim::hadare_engine::{run_with_gang, HadarESimResult};
use hadar::trace::philly::{generate, TraceConfig};
use hadar::trace::workload::materialize;

/// A queue big enough that both sharding thresholds trip: 300 parents ×
/// 60 single-GPU nodes = 18 000 matrix cells ≥ 2^14, so multi-worker
/// runs actually spawn the worker pool instead of falling back to the
/// serial path.
fn stream_queue(cluster: &ClusterSpec, n_jobs: usize)
                -> (JobQueue, JobTracker) {
    let trace = generate(&TraceConfig {
        n_jobs,
        seed: 7,
        all_at_start: true,
        max_gpus: 4,
        ..Default::default()
    });
    let mut queue = JobQueue::new();
    for j in materialize(&trace, cluster, 7) {
        queue.admit(j).unwrap();
    }
    let max_id = queue.iter().map(|j| j.id.0).max().unwrap_or(0);
    let ids = ForkIds {
        max_job_count: (max_id + 1).max(512),
    };
    let mut tracker = JobTracker::new(ids);
    let copies = 3u64;
    for j in queue.iter() {
        tracker.register(
            j.id,
            j.total_iters(),
            &(1..=copies).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
        );
    }
    (queue, tracker)
}

fn at(threads: usize) -> GangConfig {
    GangConfig {
        plan_threads: threads,
        ..GangConfig::default()
    }
}

#[test]
fn planner_is_bit_identical_at_1_2_and_8_workers() {
    let cluster = ClusterSpec::scaled(20, 1);
    let (queue, tracker) = stream_queue(&cluster, 300);
    let copies = 3u64;
    let active = queue.active_at(0.0);
    let ctx = |round: u64| RoundCtx {
        round,
        now: round as f64 * 360.0,
        slot_secs: 360.0,
        horizon: 1e7,
        queue: &queue,
        active: &active,
        delta: None,
        cluster: &cluster,
    };
    // Carry-over from a round-0 plan, so the warm path is exercised
    // with real bindings rather than the empty degradation case.
    let mut seeder = HadarE::with_gang(copies, at(1));
    let p0 = seeder.plan_round(&ctx(0), &tracker);
    assert!(!p0.allocations.is_empty());
    let prev = PrevRound::from_plan(&p0, &tracker, 10.0);

    let mut baseline: Option<(RoundPlan, RoundPlan)> = None;
    for threads in [1usize, 2, 8] {
        let cold = HadarE::with_gang(copies, at(threads))
            .plan_round_cold(&ctx(1), &tracker, &prev);
        let mut warm = HadarE::with_gang(copies, at(threads));
        let _ = warm.plan_round(&ctx(0), &tracker); // populate row cache
        let warm_plan = warm.plan_round_with(&ctx(1), &tracker, &prev);
        assert_eq!(cold.allocations, warm_plan.allocations,
                   "warm and cold must agree at {threads} workers");
        if let Some((bc, bw)) = &baseline {
            assert_eq!(bc.allocations, cold.allocations,
                       "cold plan diverged at {threads} workers");
            assert_eq!(bw.allocations, warm_plan.allocations,
                       "warm plan diverged at {threads} workers");
        } else {
            baseline = Some((cold, warm_plan));
        }
    }
}

/// The two `SimResult`s every field the engine derives from plans must
/// match on — if any plan diverged at any round, something here drifts.
fn assert_sim_identical(a: &HadarESimResult, b: &HadarESimResult,
                        label: &str) {
    assert_eq!(a.sim.ttd, b.sim.ttd, "{label}: ttd");
    assert_eq!(a.sim.jct, b.sim.jct, "{label}: jct");
    assert_eq!(a.sim.gru, b.sim.gru, "{label}: gru");
    assert_eq!(a.sim.cru, b.sim.cru, "{label}: cru");
    assert_eq!(a.sim.anu, b.sim.anu, "{label}: anu");
    assert_eq!(a.sim.rounds, b.sim.rounds, "{label}: rounds");
    assert_eq!(a.sim.preemptions, b.sim.preemptions,
               "{label}: preemptions");
    assert_eq!(a.sim.events_applied, b.sim.events_applied,
               "{label}: events applied");
    assert_eq!(a.work_log.len(), b.work_log.len(), "{label}: work log");
    for (wa, wb) in a.work_log.iter().zip(b.work_log.iter()) {
        assert_eq!((wa.round, wa.copy, wa.node, wa.gpus),
                   (wb.round, wb.copy, wb.node, wb.gpus),
                   "{label}: work-log row");
        assert_eq!(wa.steps, wb.steps, "{label}: work-log steps");
    }
}

#[test]
fn engine_results_are_bit_identical_at_1_2_and_8_workers() {
    // A churny scenario end to end: sim60, a maintenance drain mid-run,
    // staggered progress — every round's plan feeds the next round's
    // carry-over, so one nondeterministic plan anywhere cascades.
    let cluster = ClusterSpec::sim60();
    let trace = generate(&TraceConfig {
        n_jobs: 24,
        seed: 9,
        all_at_start: true,
        max_gpus: 4,
        ..Default::default()
    });
    let jobs: Vec<Job> = materialize(&trace, &cluster, 9);
    let mut events = EventTimeline::empty();
    events.push(90.0, EventKind::Maintenance { node: 3, duration: 180.0 });
    let cfg = SimConfig {
        slot_secs: 90.0,
        restart_overhead: 10.0,
        max_rounds: 5000,
        horizon: 1e7,
    };
    let base = run_with_gang(&jobs, &cluster, &events, &cfg, None, at(1))
        .unwrap();
    assert!(base.sim.rounds > 0);
    for threads in [2usize, 8] {
        let res =
            run_with_gang(&jobs, &cluster, &events, &cfg, None, at(threads))
                .unwrap();
        assert_sim_identical(&base, &res, &format!("{threads} workers"));
    }
}

#[test]
fn stale_bindings_to_removed_nodes_are_dropped_cleanly() {
    // Planner-level churn safety on a live cluster object: plan, remove
    // a node, then replan with the *stale* carry-over still naming it.
    // The row cache must invalidate, nothing may be placed on the gone
    // node, and the stale binding must not perturb equivalence with
    // cold replanning.
    let mut cluster = ClusterSpec::scaled(2, 2);
    let (queue, tracker) = stream_queue(&cluster, 12);
    let copies = 3u64;
    let active = queue.active_at(0.0);
    let mut warm = HadarE::with_gang(copies, at(1));
    let p0 = {
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        warm.plan_round(&ctx, &tracker)
    };
    let prev = PrevRound::from_plan(&p0, &tracker, 10.0);
    assert!(!prev.is_empty());
    let victim = cluster.nodes[0].id;
    cluster.remove_node(victim);
    let inval_before = warm.stats.invalidations;
    let (p_warm, p_cold) = {
        let ctx = RoundCtx {
            round: 1,
            now: 360.0,
            slot_secs: 360.0,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let cold = HadarE::with_gang(copies, at(1));
        (
            warm.plan_round_with(&ctx, &tracker, &prev),
            cold.plan_round_cold(&ctx, &tracker, &prev),
        )
    };
    assert!(warm.stats.invalidations > inval_before,
            "inventory change must invalidate the row cache");
    assert_eq!(p_warm.allocations, p_cold.allocations,
               "stale bindings broke warm/cold equivalence");
    for alloc in p_warm.allocations.values() {
        assert!(!alloc.nodes().contains(&victim),
                "placed work on the removed node {victim}");
    }
}

#[test]
fn restart_overhead_is_charged_exactly_once_per_rebind() {
    // Engine-level churn safety, exact-value: one parent bounces
    // V100 -> K80 -> (idle-keeps-model) -> back to V100 across a
    // maintenance window. Each (node, pool) rebind to a *different*
    // loaded parent pays the 10 s overhead exactly once; resuming the
    // pool's already-loaded parent is free — and the binding-aware
    // planner payoff agrees with what the engine charges.
    let cluster = ClusterSpec::new(
        "duo",
        vec![
            Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3),
            Node::new(1, "k", &[(GpuType::K80, 1)], PcieGen::Gen3),
        ],
    );
    let mut p = Job::new(0, DlModel::Lstm, 0.0, 1, 20, 100); // 2000 iters
    p.set_throughput(GpuType::V100, 2.0);
    p.set_throughput(GpuType::K80, 1.0);
    let mut events = EventTimeline::empty();
    // The fast node drains for rounds 1-2 and rejoins for round 3.
    events.push(90.0, EventKind::Maintenance { node: 0, duration: 180.0 });
    let cfg = SimConfig {
        slot_secs: 90.0,
        restart_overhead: 10.0,
        max_rounds: 100,
        horizon: 1e7,
    };
    let res = run_with_gang(std::slice::from_ref(&p), &cluster, &events,
                            &cfg, Some(1), at(1))
        .unwrap();
    // Exactly one preemption: the drain unbinding the running copy.
    assert_eq!(res.sim.preemptions, 1);
    // Round-by-round steps pin each overhead charge:
    //   r0: first load on the V100 node   -> (90-10)*2 = 160
    //   r1: drain; first load on the K80  -> (90-10)*1 =  80
    //   r2: same pool, same parent        ->  90*1     =  90
    //   r3: rejoin; rebind to the V100    -> (90-10)*2 = 160
    //       (the switch is worth it: 160 > the K80's 90 — and the
    //        planner's binding-aware payoff prices exactly that)
    //   r4: V100 keeps its parent         ->  90*2     = 180
    let expect = [(0usize, 0usize, 160.0), (1, 1, 80.0), (2, 1, 90.0),
                  (3, 0, 160.0), (4, 0, 180.0)];
    for &(round, node, steps) in &expect {
        let w: Vec<_> = res
            .work_log
            .iter()
            .filter(|w| w.round == round as u64)
            .collect();
        assert_eq!(w.len(), 1, "round {round}: one copy runs");
        assert_eq!(w[0].node, node, "round {round}: host node");
        assert!((w[0].steps - steps).abs() < 1e-9,
                "round {round}: steps {} != {steps}", w[0].steps);
    }
    assert_eq!(res.sim.jct.len(), 1, "the parent completes");
    assert_eq!(res.sim.jct.keys().next(), Some(&JobId(0)));
}
