//! Self-audit: `hadar lint` over the live `rust/src` tree, inside
//! `cargo test`. This is the same gate CI runs as a standalone job
//! (`hadar lint --json`), duplicated here so a plain local `cargo test`
//! catches a reintroduced `partial_cmp` comparator or ad-hoc thread
//! pool before a PR ever reaches CI.

use std::path::Path;

use hadar::analysis::lint_tree;

/// The live tree lints clean: no violations, no stale pragmas, no
/// pragma syntax errors. On failure the rendered report *is* the
/// assertion message, so the offending `file:line [rule]` shows up
/// directly in the test output.
#[test]
fn live_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("module graph builds");
    assert!(report.clean(), "\n{}", report.render());
}

/// The classification the rules hang off: spot-check load-bearing
/// files on both sides of the plan-path/harness split.
#[test]
fn live_tree_classification() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("module graph builds");
    let class = |file: &str| {
        report
            .files
            .iter()
            .find(|f| f.file == file)
            .unwrap_or_else(|| panic!("{file} not discovered"))
            .class
    };
    // The solvers and engines carry the determinism contract.
    assert_eq!(class("sched/hadar.rs"), "plan-path");
    assert_eq!(class("sched/hadare.rs"), "plan-path");
    assert_eq!(class("sim/engine.rs"), "plan-path");
    assert_eq!(class("jobs/queue.rs"), "plan-path");
    assert_eq!(class("forking/tracker.rs"), "plan-path");
    // …while benches under sched/ and the observers do not.
    assert_eq!(class("sched/bench.rs"), "harness");
    assert_eq!(class("obs/trace.rs"), "harness");
    assert_eq!(class("expt/runner.rs"), "harness");
    assert_eq!(class("util/stats.rs"), "harness");
    assert_eq!(class("main.rs"), "harness");
    // The graph walks `mod` declarations, so it sees the whole crate.
    assert!(report.files.len() >= 60, "{} files", report.files.len());
    assert!(report.plan_path_files() >= 15);
}

/// Every pragma in the tree is pulling its weight: the engine reports
/// stale ones as findings (checked above), and the totals confirm the
/// suppression layer is actually exercised by the live tree.
#[test]
fn live_tree_pragmas_are_used() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("module graph builds");
    assert!(report.pragmas > 0, "expected triage pragmas in the tree");
    assert!(report.suppressed >= report.pragmas);
}
