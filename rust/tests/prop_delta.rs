//! Delta-pipeline equivalence: a scheduler fed incremental round deltas
//! ([`RoundDelta`] via [`RoundCtx::delta`] + `observe_delta`, with the
//! queue driven through its indexed lifecycle API) must produce plans
//! **and** solver statistics bit-identical to full-list replanning (the
//! pre-refactor world: `active_at` scans, `delta: None`, job status
//! mutated in place) across seeded churn/preemption/completion
//! scenarios, several rounds deep, at `plan_threads` 1, 2, and 8.
//!
//! This is the non-negotiable gate on the round-pipeline refactor: the
//! delta is an *optimisation channel*, never a behaviour channel. Any
//! divergence — a plan, a `SolverStats` counter, a `WarmStats` counter
//! — is a pipeline bug, not a tuning difference.
//!
//! Two universes run side by side from identical seeds:
//!
//! * **delta universe**: its own [`JobQueue`] driven through
//!   [`JobQueue::poll_round`] / [`JobQueue::complete`] /
//!   [`JobQueue::note_preempted`], with idle boundaries merging their
//!   deltas into a carry exactly as the sim engine does, the waiting
//!   set read from [`JobQueue::waiting`], and the scheduler observing
//!   every delta;
//! * **full universe**: its own [`JobQueue`] mutated the pre-refactor
//!   way (status writes through `get_mut`), the waiting set rebuilt by
//!   [`JobQueue::active_at`] every round, and `delta: None`.

use hadar::cluster::gpu::{GpuType, PcieGen};
use hadar::cluster::node::Node;
use hadar::cluster::spec::ClusterSpec;
use hadar::forking::forker::ForkIds;
use hadar::forking::tracker::JobTracker;
use hadar::jobs::job::{Job, JobId, JobStatus};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::hadar::{Hadar, HadarConfig};
use hadar::sched::hadare::{GangConfig, HadarE, PrevRound};
use hadar::sched::{RoundCtx, RoundDelta, RoundPlan, Scheduler};
use hadar::util::prop::{check_no_shrink, Config};
use hadar::util::rng::Rng;
use std::collections::BTreeMap;

const TYPES: [GpuType; 4] =
    [GpuType::V100, GpuType::P100, GpuType::K80, GpuType::T4];

/// Random heterogeneous cluster: 3-8 nodes, one random type of 1-4 GPUs
/// per node.
fn gen_cluster(rng: &mut Rng) -> ClusterSpec {
    let n = rng.range_u(3, 8) as usize;
    let nodes = (0..n)
        .map(|id| {
            let t = *rng.choice(&TYPES);
            let cap = rng.range_u(1, 4) as usize;
            Node::new(id, &format!("n{id}"), &[(t, cap)], PcieGen::Gen3)
        })
        .collect();
    ClusterSpec::new("rand", nodes)
}

/// Random job with a staggered arrival (0-3 slots late), so scenarios
/// exercise genuine mid-run arrivals flowing through the delta.
fn gen_job(rng: &mut Rng, id: u64, slot: f64) -> Job {
    let w = [1usize, 1, 2, 2, 3, 4][rng.below(6) as usize];
    let epochs = rng.range_u(1, 8);
    let mut j = Job::new(id, DlModel::Lstm, 0.0, w, epochs, 50);
    j.arrival = slot * rng.below(4) as f64;
    let base = rng.range_f(5.0, 80.0);
    for (i, &g) in TYPES.iter().enumerate() {
        if i == 0 || rng.f64() < 0.8 {
            j.set_throughput(g, base * rng.range_f(0.1, 1.0));
        }
    }
    j
}

fn plans_equal(a: &RoundPlan, b: &RoundPlan) -> bool {
    a.allocations == b.allocations
}

/// Delta-fed Hadar vs full-list Hadar over ≥70 seeded scenarios: plans
/// and [`hadar::sched::SolverStats`] must match round for round across
/// staggered arrivals, engine-rule progress, completions, drain
/// preemptions with node removal, and idle boundaries whose deltas
/// carry forward — at `plan_threads` 1, 2, and 8 (rotated per
/// scenario; the thread count must stay a pure throughput dial in the
/// delta world too).
#[test]
fn prop_hadar_delta_fed_matches_full_replanning() {
    check_no_shrink(
        Config { cases: 70, seed: 0xDE17A1 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut cluster = gen_cluster(&mut rng);
            let slot = 360.0;
            let n_jobs = rng.range_u(3, 16);
            let mut queue_d = JobQueue::new();
            let mut queue_f = JobQueue::new();
            for id in 0..n_jobs {
                let j = gen_job(&mut rng, id, slot);
                queue_d.admit(j.clone()).unwrap();
                queue_f.admit(j).unwrap();
            }
            let cfg = HadarConfig {
                dp_job_cap: if rng.below(2) == 0 { 12 } else { 4 },
                incremental: rng.below(2) == 0,
                plan_threads: [1usize, 2, 8][rng.below(3) as usize],
                ..Default::default()
            };
            let mut sched_d = Hadar::with_config(cfg);
            let mut sched_f = Hadar::with_config(cfg);
            // Idle boundaries accumulate here, as in the sim engine.
            let mut carry = RoundDelta::default();
            // Cluster events applied since the last boundary.
            let mut pending_events = 0u64;

            for round in 0..6u64 {
                let now = round as f64 * slot;
                let mut boundary = queue_d.poll_round(now);
                boundary.events = pending_events;
                pending_events = 0;
                carry.merge(boundary);
                let active_d = queue_d.waiting();
                let active_f = queue_f.active_at(now);
                if active_d != active_f {
                    return Err(format!(
                        "round {round}: waiting sets diverged: delta \
                         {active_d:?} vs full {active_f:?}"
                    ));
                }
                if active_d.is_empty() {
                    continue; // idle boundary; `carry` keeps the delta
                }
                let delta = std::mem::take(&mut carry);
                sched_d.observe_delta(&delta, &queue_d);
                let p_d = sched_d.schedule(&RoundCtx {
                    round,
                    now,
                    slot_secs: slot,
                    horizon: 1e7,
                    queue: &queue_d,
                    active: &active_d,
                    delta: Some(&delta),
                    cluster: &cluster,
                });
                let p_f = sched_f.schedule(&RoundCtx {
                    round,
                    now,
                    slot_secs: slot,
                    horizon: 1e7,
                    queue: &queue_f,
                    active: &active_f,
                    delta: None,
                    cluster: &cluster,
                });
                if !plans_equal(&p_d, &p_f) {
                    return Err(format!(
                        "round {round} (threads {}): plans diverged: \
                         delta {:?} vs full {:?}",
                        cfg.plan_threads, p_d.allocations, p_f.allocations
                    ));
                }
                if sched_d.solver_stats() != sched_f.solver_stats() {
                    return Err(format!(
                        "round {round}: solver stats diverged: delta \
                         {:?} vs full {:?}",
                        sched_d.solver_stats(), sched_f.solver_stats()
                    ));
                }

                // Advance progress by the engine's bottleneck rule,
                // identically in both universes; completions go through
                // the queue API on the delta side and through direct
                // status writes (the pre-refactor way) on the full side.
                let scheduled = p_d.scheduled_jobs();
                for &id in &scheduled {
                    let alloc = p_d.get(id).unwrap().clone();
                    let x_min = alloc
                        .gpu_types()
                        .iter()
                        .map(|&g| {
                            queue_d.get(id).unwrap().throughput_on(g)
                        })
                        .fold(f64::INFINITY, f64::min);
                    if !x_min.is_finite() || x_min <= 0.0 {
                        continue;
                    }
                    let gain = alloc.total_gpus() as f64 * x_min * slot;
                    let done = {
                        let jd = queue_d.get_mut(id).unwrap();
                        jd.progress += gain;
                        jd.status = JobStatus::Running;
                        jd.is_complete()
                    };
                    {
                        let jf = queue_f.get_mut(id).unwrap();
                        jf.progress += gain;
                        jf.status = JobStatus::Running;
                    }
                    if done {
                        queue_d.complete(id, now + slot);
                        sched_d.job_completed(id);
                        let jf = queue_f.get_mut(id).unwrap();
                        jf.status = JobStatus::Completed;
                        jf.finish_time = Some(now + slot);
                        sched_f.job_completed(id);
                    }
                }

                // Random drain: drop a node and preempt the jobs whose
                // placement touched it — identically in both universes,
                // with the delta queue additionally noting the
                // preemption and the event for the next boundary.
                if rng.f64() < 0.4 && cluster.nodes.len() > 1 {
                    let victim = cluster.nodes
                        [rng.below(cluster.nodes.len() as u64) as usize]
                        .id;
                    cluster.remove_node(victim);
                    pending_events += 1;
                    for &id in &scheduled {
                        let touches = p_d
                            .get(id)
                            .map(|a| a.nodes().contains(&victim))
                            .unwrap_or(false);
                        let live = queue_d
                            .get(id)
                            .map_or(false, |j| !j.is_complete());
                        if touches && live {
                            sched_d.preempt(id);
                            queue_d.note_preempted(id);
                            if let Some(j) = queue_d.get_mut(id) {
                                j.status = JobStatus::Queued;
                            }
                            sched_f.preempt(id);
                            if let Some(j) = queue_f.get_mut(id) {
                                j.status = JobStatus::Queued;
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Random parent for the HadarE scenarios: a throughput entry for most
/// of the cluster's types, arrival staggered 0-2 slots.
fn gen_parent(rng: &mut Rng, id: u64, cluster: &ClusterSpec, slot: f64)
              -> Job {
    let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, rng.range_u(1, 10), 50);
    j.arrival = slot * rng.below(3) as f64;
    for (ti, &g) in cluster.gpu_types().iter().enumerate() {
        if ti == 0 || rng.f64() < 0.85 {
            j.set_throughput(g, rng.range_f(0.5, 60.0));
        }
    }
    j
}

/// Random cluster for the HadarE scenarios: paper presets and scaled
/// multi-GPU shapes — the domains the warm-row signature skip must stay
/// exact on.
fn gen_hadare_cluster(rng: &mut Rng) -> ClusterSpec {
    match rng.below(3) {
        0 => ClusterSpec::testbed5(),
        1 => ClusterSpec::big(2, 4),
        _ => ClusterSpec::scaled(rng.range_u(1, 3) as usize,
                                 rng.range_u(1, 4) as usize),
    }
}

/// Delta-fed HadarE vs full-list HadarE over ≥70 seeded scenarios:
/// [`HadarE::plan_round_with`] reading `ctx.delta` (waiting set from the
/// indexed queue, `events == 0` rounds eligible for the row-signature
/// skip) must produce plans and [`hadar::sched::hadare::WarmStats`]
/// identical to the same planner fed the full `active_at` list with
/// `delta: None` (signature recomputed every round) — across arrivals,
/// copy progress with mid-run completions, node churn (with stale
/// carry-over bindings kept), and both gang modes.
#[test]
fn prop_hadare_delta_fed_matches_full_replanning() {
    check_no_shrink(
        Config { cases: 70, seed: 0xDE17A2 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut cluster = gen_hadare_cluster(&mut rng);
            let slot = 360.0;
            let n_nodes = cluster.nodes.len() as u64;
            let copies = rng.range_u(1, n_nodes + 2);
            let gang = if rng.below(2) == 0 {
                GangConfig::default()
            } else {
                GangConfig::shared()
            };
            let ids = ForkIds { max_job_count: 64 };
            let mut tracker = JobTracker::new(ids);
            let mut queue_d = JobQueue::new();
            let mut queue_f = JobQueue::new();
            let n_parents = rng.range_u(1, 8);
            for id in 0..n_parents {
                let j = gen_parent(&mut rng, id, &cluster, slot);
                tracker.register(
                    j.id,
                    j.total_iters(),
                    &(1..=copies)
                        .map(|i| ids.copy_id(j.id, i))
                        .collect::<Vec<_>>(),
                );
                queue_d.admit(j.clone()).unwrap();
                queue_f.admit(j).unwrap();
            }
            let mut plan_d = HadarE::with_gang(copies, gang);
            let mut plan_f = HadarE::with_gang(copies, gang);
            // Shared carry-over bindings, as the engine maintains them.
            let mut bind_map: BTreeMap<(usize, GpuType), JobId> =
                BTreeMap::new();
            let mut pending_events = 0u64;

            for round in 0..5u64 {
                let now = round as f64 * slot;
                let mut delta = queue_d.poll_round(now);
                delta.events = pending_events;
                pending_events = 0;
                let active_d = queue_d.waiting();
                let active_f = queue_f.active_at(now);
                let mut prev = PrevRound::new(10.0);
                for (&(node, g), &pid) in &bind_map {
                    prev.bind(node, g, pid);
                }
                let p_d = plan_d.plan_round_with(
                    &RoundCtx {
                        round,
                        now,
                        slot_secs: slot,
                        horizon: 1e7,
                        queue: &queue_d,
                        active: &active_d,
                        delta: Some(&delta),
                        cluster: &cluster,
                    },
                    &tracker,
                    &prev,
                );
                let p_f = plan_f.plan_round_with(
                    &RoundCtx {
                        round,
                        now,
                        slot_secs: slot,
                        horizon: 1e7,
                        queue: &queue_f,
                        active: &active_f,
                        delta: None,
                        cluster: &cluster,
                    },
                    &tracker,
                    &prev,
                );
                if !plans_equal(&p_d, &p_f) {
                    return Err(format!(
                        "round {round} (copies {copies}, shared {}): \
                         plans diverged: delta {:?} vs full {:?}",
                        gang.share_nodes, p_d.allocations, p_f.allocations
                    ));
                }
                if plan_d.stats != plan_f.stats {
                    return Err(format!(
                        "round {round}: warm stats diverged: delta {:?} \
                         vs full {:?}",
                        plan_d.stats, plan_f.stats
                    ));
                }

                // Advance the shared tracker from the agreed plan;
                // parent completions go through the delta queue's
                // lifecycle API and are notified to both planners.
                bind_map.clear();
                for (&copy, alloc) in &p_d.allocations {
                    let parent = tracker.resolve(copy);
                    for (&(node, g), _) in alloc.slots.iter() {
                        bind_map.insert((node, g), parent);
                    }
                    if let Some(j) = queue_d.get(parent) {
                        let g = alloc.gpu_types()[0];
                        let x = j.throughput_on(g);
                        let steps = if rng.f64() < 0.15 {
                            1e9
                        } else {
                            x * slot * rng.f64()
                        };
                        tracker.report_steps(copy, steps);
                    }
                    if tracker.is_parent_complete(parent)
                        && queue_d
                            .get(parent)
                            .map_or(false, |j| {
                                j.status != JobStatus::Completed
                            })
                    {
                        plan_d.job_completed(parent);
                        plan_f.job_completed(parent);
                        queue_d.complete(parent, now + slot);
                    }
                }

                // Churn: occasionally drop a node, keep its stale
                // bindings (churn-safety), and stamp the event so the
                // delta side recomputes the slot signature.
                if rng.f64() < 0.3 && cluster.nodes.len() > 1 {
                    let victim = cluster.nodes
                        [rng.below(cluster.nodes.len() as u64) as usize]
                        .id;
                    cluster.remove_node(victim);
                    pending_events += 1;
                }
            }
            Ok(())
        },
    );
}
