//! Plan equivalence: the zero-clone Hadar solver must return `RoundPlan`s
//! **identical** to the frozen pre-optimisation reference
//! (`sched::reference::RefHadar`) — same jobs selected, same pools, same
//! counts — across seeded random (cluster, queue) scenarios, on both solve
//! paths (exact DP and payoff-density greedy), in incremental mode, and
//! through drain preemptions and completions. This is the non-negotiable
//! gate on the perf rework: any divergence is a solver bug, not a tuning
//! difference. The same file pins the speculative sharded greedy: plans
//! must be bit-identical at `plan_threads` 1, 2, and 8 (the
//! `HADAR_PLAN_THREADS` knob), so the worker count is a pure throughput
//! dial, never a behaviour dial.
//!
//! The same contract pins the gang HadarE planner to its frozen
//! single-GPU predecessor (`sched::reference::RefHadarE`) on single-GPU
//! clusters, where "one GPU" and "whole node" coincide — the rework must
//! be behaviour-preserving there, and only there (on multi-GPU clusters
//! the divergence *is* the PR-4 bugfix). The partial-node rework pinned
//! no new reference: `share_nodes = false` is the compatibility mode
//! (checked against `RefHadarE` below), and `share_nodes = true`
//! degenerates to the same plans on single-pool nodes, which the same
//! property drives as a third planner.

use hadar::cluster::gpu::{GpuType, PcieGen};
use hadar::cluster::node::Node;
use hadar::cluster::spec::ClusterSpec;
use hadar::forking::forker::ForkIds;
use hadar::forking::tracker::JobTracker;
use hadar::jobs::job::{Job, JobId};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::hadar::{Hadar, HadarConfig};
use hadar::sched::hadare::HadarE;
use hadar::sched::reference::{RefHadar, RefHadarE};
use hadar::sched::{RoundCtx, RoundPlan, Scheduler};
use hadar::util::prop::{check_no_shrink, Config};
use hadar::util::rng::Rng;

const TYPES: [GpuType; 4] =
    [GpuType::V100, GpuType::P100, GpuType::K80, GpuType::T4];

/// Random heterogeneous cluster: 3-8 nodes, one random type of 1-4 GPUs
/// per node.
fn gen_cluster(rng: &mut Rng) -> ClusterSpec {
    let n = rng.range_u(3, 8) as usize;
    let nodes = (0..n)
        .map(|id| {
            let t = *rng.choice(&TYPES);
            let cap = rng.range_u(1, 4) as usize;
            Node::new(id, &format!("n{id}"), &[(t, cap)], PcieGen::Gen3)
        })
        .collect();
    ClusterSpec::new("rand", nodes)
}

/// Random job over the four bench types; some types are missing from some
/// rows (heterogeneous support), all present entries are positive.
fn gen_job(rng: &mut Rng, id: u64) -> Job {
    let w = [1usize, 1, 2, 2, 3, 4][rng.below(6) as usize];
    let epochs = rng.range_u(1, 8);
    let mut j = Job::new(id, DlModel::Lstm, 0.0, w, epochs, 50);
    let base = rng.range_f(5.0, 80.0);
    for (i, &g) in TYPES.iter().enumerate() {
        if i == 0 || rng.f64() < 0.8 {
            j.set_throughput(g, base * rng.range_f(0.1, 1.0));
        }
    }
    j
}

fn ctx<'a>(now: f64, queue: &'a JobQueue, active: &'a [JobId],
           cluster: &'a ClusterSpec) -> RoundCtx<'a> {
    RoundCtx {
        round: 0,
        now,
        slot_secs: 360.0,
        horizon: 1e7,
        queue,
        active,
        delta: None,
        cluster,
    }
}

fn plans_equal(a: &RoundPlan, b: &RoundPlan) -> bool {
    a.allocations == b.allocations
}

/// Single-round equivalence over ≥70 random scenarios, alternating the
/// DP and greedy paths via a randomised `dp_job_cap`.
#[test]
fn prop_single_round_plans_identical() {
    check_no_shrink(
        Config { cases: 70, seed: 0x5EED1 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = gen_cluster(&mut rng);
            let n_jobs = rng.range_u(1, 14);
            let mut queue = JobQueue::new();
            for id in 0..n_jobs {
                queue.admit(gen_job(&mut rng, id)).unwrap();
            }
            let cfg = HadarConfig {
                // Half the scenarios force the greedy path.
                dp_job_cap: if rng.below(2) == 0 { 12 } else { 4 },
                min_efficiency: if rng.below(2) == 0 { 0.0 } else { 0.1 },
                ..Default::default()
            };
            let active = queue.active_at(0.0);
            let mut opt = Hadar::with_config(cfg);
            let mut reference = RefHadar::with_config(cfg);
            let c = ctx(0.0, &queue, &active, &cluster);
            let p_opt = opt.schedule(&c);
            let p_ref = reference.schedule(&c);
            if !plans_equal(&p_opt, &p_ref) {
                return Err(format!(
                    "plans diverged: opt {:?} vs ref {:?}",
                    p_opt.allocations, p_ref.allocations
                ));
            }
            Ok(())
        },
    );
}

/// Multi-round equivalence over ≥50 random scenarios in **incremental
/// mode**, with progress advancing between rounds, random **drain
/// preemptions** (both solvers told identically, as the engine does),
/// node removals, and completion notifications.
#[test]
fn prop_incremental_rounds_with_preemption_identical() {
    check_no_shrink(
        Config { cases: 50, seed: 0x5EED2 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut cluster = gen_cluster(&mut rng);
            let n_jobs = rng.range_u(2, 10);
            let mut queue = JobQueue::new();
            for id in 0..n_jobs {
                queue.admit(gen_job(&mut rng, id)).unwrap();
            }
            let cfg = HadarConfig {
                incremental: true,
                dp_job_cap: if rng.below(2) == 0 { 12 } else { 3 },
                ..Default::default()
            };
            let mut opt = Hadar::with_config(cfg);
            let mut reference = RefHadar::with_config(cfg);
            let slot = 360.0;

            for round in 0..5u64 {
                let now = round as f64 * slot;
                let active = queue.active_at(now);
                if active.is_empty() {
                    break;
                }
                let (p_opt, p_ref) = {
                    let c = ctx(now, &queue, &active, &cluster);
                    (opt.schedule(&c), reference.schedule(&c))
                };
                if !plans_equal(&p_opt, &p_ref) {
                    return Err(format!(
                        "round {round}: plans diverged: opt {:?} vs ref {:?}",
                        p_opt.allocations, p_ref.allocations
                    ));
                }

                // Advance progress exactly as the engine's bottleneck rule
                // does, and notify completions on both solvers.
                let scheduled = p_opt.scheduled_jobs();
                for &id in &scheduled {
                    let alloc = p_opt.get(id).unwrap().clone();
                    let job = queue.get_mut(id).unwrap();
                    let x_min = alloc
                        .gpu_types()
                        .iter()
                        .map(|&g| job.throughput_on(g))
                        .fold(f64::INFINITY, f64::min);
                    if x_min.is_finite() && x_min > 0.0 {
                        job.progress += alloc.total_gpus() as f64
                            * x_min
                            * slot;
                    }
                    if job.is_complete() {
                        opt.job_completed(id);
                        reference.job_completed(id);
                    }
                }

                // Random drain: drop a node and preempt the jobs whose
                // current placement touched it — identically on both.
                if rng.f64() < 0.35 && cluster.nodes.len() > 1 {
                    let victim =
                        cluster.nodes[rng.below(cluster.nodes.len() as u64)
                            as usize]
                            .id;
                    cluster.remove_node(victim);
                    for &id in &scheduled {
                        let touches = p_opt
                            .get(id)
                            .map(|a| a.nodes().contains(&victim))
                            .unwrap_or(false);
                        if touches {
                            opt.preempt(id);
                            reference.preempt(id);
                        }
                    }
                } else if rng.f64() < 0.3 {
                    // Plain scheduler-side preemption of one random
                    // scheduled job (the engine's drain path).
                    if let Some(&id) = scheduled.first() {
                        opt.preempt(id);
                        reference.preempt(id);
                    }
                }
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------------- HadarE

/// Random *single-GPU* cluster: one of the paper's §VI clusters
/// (`aws5`, `testbed5`) or a random 2-8-node mix of one-GPU nodes — the
/// domain on which the gang rework must be behaviour-preserving.
fn gen_single_gpu_cluster(rng: &mut Rng) -> ClusterSpec {
    match rng.below(3) {
        0 => ClusterSpec::aws5(),
        1 => ClusterSpec::testbed5(),
        _ => {
            let n = rng.range_u(2, 8) as usize;
            let nodes = (0..n)
                .map(|id| {
                    let t = *rng.choice(&TYPES);
                    Node::new(id, &format!("s{id}"), &[(t, 1)],
                              PcieGen::Gen3)
                })
                .collect();
            ClusterSpec::new("rand-single", nodes)
        }
    }
}

/// Random HadarE parent: a throughput entry for most of the cluster's
/// types (some missing — heterogeneous support), all present entries
/// positive.
fn gen_parent(rng: &mut Rng, id: u64, cluster: &ClusterSpec) -> Job {
    let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, rng.range_u(1, 10), 50);
    for (ti, &g) in cluster.gpu_types().iter().enumerate() {
        if ti == 0 || rng.f64() < 0.85 {
            j.set_throughput(g, rng.range_f(0.5, 60.0));
        }
    }
    j
}

/// Gang HadarE equivalence on single-GPU clusters over ≥70 seeded
/// scenarios: the flat-table planner in whole-node compatibility mode
/// (`share_nodes = false`, explicitly pinned), the same planner in
/// partial-node mode (`share_nodes = true`, which degenerates to the
/// identical slot inventory on single-pool nodes), and the frozen
/// `RefHadarE` must agree plan for plan across multiple rounds, with
/// copy progress (including mid-run completions) advancing the shared
/// tracker between rounds and the copy budget varying from starved (1)
/// to beyond the node count.
#[test]
fn prop_hadare_single_gpu_plans_identical() {
    use hadar::sched::hadare::GangConfig;
    check_no_shrink(
        Config { cases: 70, seed: 0x5EED3 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = gen_single_gpu_cluster(&mut rng);
            let n_nodes = cluster.nodes.len() as u64;
            let copies = rng.range_u(1, n_nodes + 2);
            let ids = ForkIds { max_job_count: 64 };
            let mut tracker = JobTracker::new(ids);
            let mut queue = JobQueue::new();
            let n_parents = rng.range_u(1, 8);
            for id in 0..n_parents {
                let j = gen_parent(&mut rng, id, &cluster);
                tracker.register(
                    j.id,
                    j.total_iters(),
                    &(1..=copies)
                        .map(|i| ids.copy_id(j.id, i))
                        .collect::<Vec<_>>(),
                );
                queue.admit(j).unwrap();
            }
            // The compatibility mode is pinned explicitly (not via the
            // Default impl), so a future default flip cannot silently
            // drop this equivalence.
            let compat = GangConfig {
                share_nodes: false,
                ..GangConfig::default()
            };
            let mut opt = HadarE::with_gang(copies, compat);
            let mut shared =
                HadarE::with_gang(copies, GangConfig::shared());
            let mut reference = RefHadarE::new(copies);
            let slot = 360.0;

            for round in 0..4u64 {
                let (p_opt, p_shared, p_ref) = {
                    let c = ctx(round as f64 * slot, &queue, &[], &cluster);
                    (
                        opt.plan_round(&c, &tracker),
                        shared.plan_round(&c, &tracker),
                        reference.plan_round(&c, &tracker),
                    )
                };
                if !plans_equal(&p_opt, &p_ref) {
                    return Err(format!(
                        "round {round} (copies {copies}): plans diverged: \
                         opt {:?} vs ref {:?}",
                        p_opt.allocations, p_ref.allocations
                    ));
                }
                if !plans_equal(&p_shared, &p_ref) {
                    return Err(format!(
                        "round {round} (copies {copies}): shared-mode \
                         plan diverged on a single-GPU cluster: shared \
                         {:?} vs ref {:?}",
                        p_shared.allocations, p_ref.allocations
                    ));
                }
                if p_opt.allocations.is_empty() {
                    break; // everything finished
                }
                // Advance: each scheduled copy reports a random share of
                // its single-GPU slot capacity (occasionally a huge jump
                // so mid-run parent completions are exercised).
                for (&copy, alloc) in &p_opt.allocations {
                    let parent = tracker.resolve(copy);
                    if let Some(j) = queue.get(parent) {
                        let g = alloc.gpu_types()[0];
                        let x = j.throughput_on(g);
                        let steps = if rng.f64() < 0.1 {
                            1e9
                        } else {
                            x * slot * rng.f64()
                        };
                        tracker.report_steps(copy, steps);
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------- HadarE warm start

/// Random cluster for the warm-start equivalence domain: the paper
/// presets (sim60, big:2x4), a small `scaled:NxG` multi-GPU preset, or
/// the single-GPU mix — multi-pool and multi-GPU shapes included, since
/// the warm path must agree with cold replanning everywhere, not just on
/// the single-GPU compatibility domain.
fn gen_warm_cluster(rng: &mut Rng) -> ClusterSpec {
    match rng.below(4) {
        0 => ClusterSpec::sim60(),
        1 => ClusterSpec::big(2, 4),
        2 => ClusterSpec::scaled(rng.range_u(1, 3) as usize,
                                 rng.range_u(1, 4) as usize),
        _ => gen_single_gpu_cluster(rng),
    }
}

/// Warm-start equivalence over ≥70 seeded scenarios: with *any*
/// carry-over bindings — including stale ones referencing removed nodes
/// — [`HadarE::plan_round_with`] (cached rows, pruned candidate scan)
/// must produce plans identical to [`HadarE::plan_round_cold`] (full
/// matrix rebuild) on the same round, across multiple rounds with
/// staggered arrivals, progress, completions, and node churn. Both modes
/// (whole-node and partial-node gangs) are driven.
#[test]
fn prop_hadare_warm_start_equals_cold_replanning() {
    use hadar::sched::hadare::{GangConfig, PrevRound};
    use std::collections::BTreeMap;
    check_no_shrink(
        Config { cases: 70, seed: 0x5EED4 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut cluster = gen_warm_cluster(&mut rng);
            let n_nodes = cluster.nodes.len() as u64;
            let copies = rng.range_u(1, n_nodes + 2);
            let gang = if rng.below(2) == 0 {
                GangConfig::default()
            } else {
                GangConfig::shared()
            };
            let ids = ForkIds { max_job_count: 64 };
            let mut tracker = JobTracker::new(ids);
            let mut queue = JobQueue::new();
            let slot = 360.0;
            let n_parents = rng.range_u(1, 8);
            for id in 0..n_parents {
                let mut j = gen_parent(&mut rng, id, &cluster);
                // ~1/3 of parents arrive one or two rounds late.
                j.arrival = slot * rng.below(3) as f64;
                tracker.register(
                    j.id,
                    j.total_iters(),
                    &(1..=copies)
                        .map(|i| ids.copy_id(j.id, i))
                        .collect::<Vec<_>>(),
                );
                queue.admit(j).unwrap();
            }
            let mut warm = HadarE::with_gang(copies, gang);
            // Persistent (node, pool) -> parent carry-over, exactly as
            // the engine maintains `prev_binding` — including stale
            // entries for nodes removed below.
            let mut bind_map: BTreeMap<(usize, GpuType), JobId> =
                BTreeMap::new();

            for round in 0..4u64 {
                let now = round as f64 * slot;
                let mut prev = PrevRound::new(10.0);
                for (&(node, g), &pid) in &bind_map {
                    prev.bind(node, g, pid);
                }
                let (p_warm, p_cold) = {
                    let c = ctx(now, &queue, &[], &cluster);
                    let cold = HadarE::with_gang(copies, gang);
                    (
                        warm.plan_round_with(&c, &tracker, &prev),
                        cold.plan_round_cold(&c, &tracker, &prev),
                    )
                };
                if !plans_equal(&p_warm, &p_cold) {
                    return Err(format!(
                        "round {round} (copies {copies}, shared \
                         {}, {} bindings): warm plan diverged from cold: \
                         warm {:?} vs cold {:?}",
                        gang.share_nodes,
                        prev.len(),
                        p_warm.allocations,
                        p_cold.allocations
                    ));
                }
                if p_warm.allocations.is_empty() && bind_map.is_empty() {
                    break;
                }
                // Next round's carry-over is this round's plan.
                bind_map.clear();
                for (&copy, alloc) in &p_warm.allocations {
                    let parent = tracker.resolve(copy);
                    for (&(node, g), _) in alloc.slots.iter() {
                        bind_map.insert((node, g), parent);
                    }
                    if let Some(j) = queue.get(parent) {
                        let g = alloc.gpu_types()[0];
                        let x = j.throughput_on(g);
                        let steps = if rng.f64() < 0.1 {
                            1e9
                        } else {
                            x * slot * rng.f64()
                        };
                        tracker.report_steps(copy, steps);
                    }
                    if tracker.is_parent_complete(parent) {
                        warm.job_completed(parent);
                    }
                }
                // Churn: occasionally drop a node but *keep* its stale
                // bindings in the carry-over — the planner must ignore
                // them (the churn-safety contract).
                if rng.f64() < 0.25 && cluster.nodes.len() > 1 {
                    let victim = cluster.nodes
                        [rng.below(cluster.nodes.len() as u64) as usize]
                        .id;
                    cluster.remove_node(victim);
                }
            }
            Ok(())
        },
    );
}

/// Degradation exactness over ≥40 seeded scenarios: a warm planner
/// handed an **empty** carry-over must plan identically to a fresh
/// planner's [`HadarE::plan_round`] — even with a populated row cache —
/// so engines that never thread bindings lose nothing and change
/// nothing.
#[test]
fn prop_hadare_empty_carry_over_degrades_to_plan_round() {
    use hadar::sched::hadare::{GangConfig, PrevRound};
    check_no_shrink(
        Config { cases: 40, seed: 0x5EED5 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = gen_warm_cluster(&mut rng);
            let n_nodes = cluster.nodes.len() as u64;
            let copies = rng.range_u(1, n_nodes + 2);
            let gang = if rng.below(2) == 0 {
                GangConfig::default()
            } else {
                GangConfig::shared()
            };
            let ids = ForkIds { max_job_count: 64 };
            let mut tracker = JobTracker::new(ids);
            let mut queue = JobQueue::new();
            let n_parents = rng.range_u(1, 6);
            for id in 0..n_parents {
                let j = gen_parent(&mut rng, id, &cluster);
                tracker.register(
                    j.id,
                    j.total_iters(),
                    &(1..=copies)
                        .map(|i| ids.copy_id(j.id, i))
                        .collect::<Vec<_>>(),
                );
                queue.admit(j).unwrap();
            }
            let mut warm = HadarE::with_gang(copies, gang);
            let slot = 360.0;
            for round in 0..3u64 {
                let (p_warm, p_fresh) = {
                    let c = ctx(round as f64 * slot, &queue, &[], &cluster);
                    let mut fresh = HadarE::with_gang(copies, gang);
                    (
                        warm.plan_round_with(&c, &tracker,
                                             &PrevRound::empty()),
                        fresh.plan_round(&c, &tracker),
                    )
                };
                if !plans_equal(&p_warm, &p_fresh) {
                    return Err(format!(
                        "round {round} (copies {copies}): empty carry-over \
                         did not degrade to plan_round: warm {:?} vs fresh \
                         {:?}",
                        p_warm.allocations, p_fresh.allocations
                    ));
                }
                if p_warm.allocations.is_empty() {
                    break;
                }
                for (&copy, alloc) in &p_warm.allocations {
                    let parent = tracker.resolve(copy);
                    if let Some(j) = queue.get(parent) {
                        let g = alloc.gpu_types()[0];
                        tracker.report_steps(
                            copy,
                            j.throughput_on(g) * slot * rng.f64(),
                        );
                    }
                    if tracker.is_parent_complete(parent) {
                        warm.job_completed(parent);
                    }
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------ Hadar speculative sharding

/// Random cluster for the sharding domain: the small heterogeneous mix
/// above, or a `scaled:NxG` preset large enough that a speculative batch
/// exceeds the serial-fallback threshold and the worker shards genuinely
/// run (small clusters exercise the conflict/rescore path instead, since
/// nearly every commit dirties the types the next job wants).
fn gen_shard_cluster(rng: &mut Rng) -> ClusterSpec {
    if rng.below(2) == 0 {
        gen_cluster(rng)
    } else {
        ClusterSpec::scaled(rng.range_u(4, 12) as usize,
                            rng.range_u(2, 8) as usize)
    }
}

/// Thread-count invariance over ≥70 seeded scenarios: with speculative
/// parallel FIND_ALLOC scoring and the deterministic density-order
/// commit, [`Hadar`] must produce plans **bit-identical** at
/// `plan_threads` 1, 2, and 8 — and identical to the frozen serial
/// [`RefHadar`] — across multiple rounds with progress, completions,
/// preemptions, and node churn, on both the DP and greedy regimes
/// (mirroring `prop_hadare_warm_start_equals_cold_replanning` in shape).
#[test]
fn prop_hadar_sharded_plans_thread_count_invariant() {
    check_no_shrink(
        Config { cases: 70, seed: 0x5EED6 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut cluster = gen_shard_cluster(&mut rng);
            let n_jobs = rng.range_u(8, 40);
            let mut queue = JobQueue::new();
            for id in 0..n_jobs {
                queue.admit(gen_job(&mut rng, id)).unwrap();
            }
            let base = HadarConfig {
                // Half the scenarios force the greedy path; the other
                // half leave the DP open for small fronts. Incremental
                // carry-over is driven half the time.
                dp_job_cap: if rng.below(2) == 0 { 12 } else { 4 },
                min_efficiency: if rng.below(2) == 0 { 0.0 } else { 0.1 },
                incremental: rng.below(2) == 0,
                ..Default::default()
            };
            let mut solvers: Vec<Hadar> = [1usize, 2, 8]
                .iter()
                .map(|&t| {
                    Hadar::with_config(HadarConfig {
                        plan_threads: t,
                        ..base
                    })
                })
                .collect();
            let mut reference = RefHadar::with_config(base);
            let slot = 360.0;

            for round in 0..4u64 {
                let now = round as f64 * slot;
                let active = queue.active_at(now);
                if active.is_empty() {
                    break;
                }
                let (plans, p_ref) = {
                    let c = ctx(now, &queue, &active, &cluster);
                    let plans: Vec<RoundPlan> = solvers
                        .iter_mut()
                        .map(|s| s.schedule(&c))
                        .collect();
                    (plans, reference.schedule(&c))
                };
                for (i, p) in plans.iter().enumerate() {
                    if !plans_equal(p, &p_ref) {
                        return Err(format!(
                            "round {round}: plan at plan_threads {} \
                             diverged from serial reference: {:?} vs \
                             {:?}",
                            [1, 2, 8][i],
                            p.allocations,
                            p_ref.allocations
                        ));
                    }
                }

                // Advance progress by the engine's bottleneck rule and
                // notify completions identically on every solver.
                let p0 = &plans[0];
                let scheduled = p0.scheduled_jobs();
                for &id in &scheduled {
                    let alloc = p0.get(id).unwrap().clone();
                    let job = queue.get_mut(id).unwrap();
                    let x_min = alloc
                        .gpu_types()
                        .iter()
                        .map(|&g| job.throughput_on(g))
                        .fold(f64::INFINITY, f64::min);
                    if x_min.is_finite() && x_min > 0.0 {
                        job.progress +=
                            alloc.total_gpus() as f64 * x_min * slot;
                    }
                    if job.is_complete() {
                        for s in &mut solvers {
                            s.job_completed(id);
                        }
                        reference.job_completed(id);
                    }
                }

                // Random drain: drop a node and preempt the jobs whose
                // placement touched it — identically on all four
                // solvers, as the engine does.
                if rng.f64() < 0.35 && cluster.nodes.len() > 1 {
                    let victim = cluster.nodes
                        [rng.below(cluster.nodes.len() as u64) as usize]
                        .id;
                    cluster.remove_node(victim);
                    for &id in &scheduled {
                        let touches = p0
                            .get(id)
                            .map(|a| a.nodes().contains(&victim))
                            .unwrap_or(false);
                        if touches {
                            for s in &mut solvers {
                                s.preempt(id);
                            }
                            reference.preempt(id);
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
