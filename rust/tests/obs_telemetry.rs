//! End-to-end guarantees of the `obs` telemetry subsystem:
//!
//! * observation is **inert** — the same seed produces identical plans
//!   and identical non-timing telemetry whether tracing/metrics are
//!   enabled or not;
//! * the disabled path is **free at the counter level** — a simulation
//!   with `obs` off never enters a span;
//! * telemetry streams are valid JSONL with one record per scheduling
//!   round.
//!
//! The obs enabled flag, span table, and metrics registry are process
//! globals, so every test here serialises on the shared test lock.

use hadar::cluster::events::EventTimeline;
use hadar::expt::spec::{ClusterRef, WorkloadSpec};
use hadar::jobs::queue::JobQueue;
use hadar::obs;
use hadar::obs::export::TelemetrySink;
use hadar::sched;
use hadar::sched::hadare::GangConfig;
use hadar::sim::engine::{self, SimConfig, SimResult};
use hadar::sim::hadare_engine;
use hadar::util::log::test_lock;

/// Run `hadar` on a sim60 trace with an in-memory non-timing telemetry
/// sink, returning the result (with timeline) and the telemetry text.
fn run_hadar_sim60() -> (SimResult, String) {
    let cluster = ClusterRef::Preset("sim60".into()).resolve().unwrap();
    let jobs = WorkloadSpec::Trace {
        n_jobs: 24,
        max_gpus: 4,
        all_at_start: true,
        hours_scale: 0.05,
    }
    .build_jobs(&cluster, 7)
    .unwrap();
    let mut queue = JobQueue::new();
    for j in jobs {
        queue.admit(j).unwrap();
    }
    let mut scheduler = sched::by_name("hadar").unwrap();
    let mut sink = TelemetrySink::in_memory(false);
    let res = engine::run_observed(
        &mut queue,
        scheduler.as_mut(),
        &cluster,
        &EventTimeline::empty(),
        &SimConfig {
            slot_secs: 360.0,
            ..Default::default()
        },
        true,
        Some(&mut sink),
    )
    .unwrap();
    let text = sink.contents().unwrap().to_string();
    (res, text)
}

/// Run `hadare-shared` (per-pool gangs) on the big8 M-3 mix with an
/// in-memory non-timing sink.
fn run_shared_big8() -> (SimResult, String) {
    let cluster = ClusterRef::Preset("big8".into()).resolve().unwrap();
    let jobs = WorkloadSpec::Mix {
        name: "M-3".into(),
        epochs_scale: 0.2,
    }
    .build_jobs(&cluster, 0)
    .unwrap();
    let mut sink = TelemetrySink::in_memory(false);
    let res = hadare_engine::run_with_gang_observed(
        &jobs,
        &cluster,
        &EventTimeline::empty(),
        &SimConfig {
            slot_secs: 90.0,
            ..Default::default()
        },
        None,
        GangConfig::shared(),
        Some(&mut sink),
    )
    .unwrap();
    let text = sink.contents().unwrap().to_string();
    (res.sim, text)
}

#[test]
fn tracing_on_or_off_yields_identical_plans_and_telemetry_sim60() {
    let _guard = test_lock();
    obs::reset();
    obs::set_enabled(false);
    let (res_off, text_off) = run_hadar_sim60();
    obs::set_enabled(true);
    let (res_on, text_on) = run_hadar_sim60();
    obs::set_enabled(false);
    obs::reset();

    assert_eq!(res_off.jct, res_on.jct, "JCTs must not depend on obs");
    assert_eq!(res_off.rounds, res_on.rounds);
    assert_eq!(res_off.timeline, res_on.timeline,
               "per-round plans must be identical with tracing on or off");
    assert_eq!(text_off, text_on,
               "non-timing telemetry must be byte-identical");
    assert!(!text_off.is_empty());
}

#[test]
fn tracing_on_or_off_yields_identical_plans_and_telemetry_big8() {
    let _guard = test_lock();
    obs::reset();
    obs::set_enabled(false);
    let (res_off, text_off) = run_shared_big8();
    obs::set_enabled(true);
    let (res_on, text_on) = run_shared_big8();
    obs::set_enabled(false);
    obs::reset();

    assert_eq!(res_off.jct, res_on.jct);
    assert_eq!(res_off.rounds, res_on.rounds);
    assert_eq!(text_off, text_on);
    // Scheduler label distinguishes the per-pool mode in the stream.
    assert!(text_off.contains("\"scheduler\":\"hadare-shared\""),
            "{}", &text_off[..text_off.len().min(200)]);
}

#[test]
fn disabled_obs_never_enters_a_span() {
    let _guard = test_lock();
    obs::reset();
    obs::set_enabled(false);
    let before = obs::trace::enters();
    // Raw span overhead guard: counter-based, not wall-clock, so it
    // cannot flake on loaded CI machines.
    for _ in 0..10_000 {
        let _s = obs::trace::span("obs.test.disabled");
    }
    // A full simulation with obs off must not enter spans either.
    let (res, _) = run_hadar_sim60();
    assert!(res.rounds > 0);
    assert_eq!(obs::trace::enters(), before,
               "disabled spans must never hit the slow path");
    obs::trace::flush();
    assert!(!obs::trace::folded().contains("obs.test.disabled"));
}

#[test]
fn enabled_obs_collects_spans_and_metrics() {
    let _guard = test_lock();
    obs::reset();
    obs::set_enabled(true);
    let (res, _) = run_hadar_sim60();
    obs::set_enabled(false);
    let folded = obs::trace::folded();
    assert!(folded.contains("sim.round"), "{folded}");
    assert!(folded.contains("sim.round;sched.schedule;hadar.schedule"),
            "nested span paths recorded: {folded}");
    let prom =
        hadar::obs::export::prometheus(hadar::obs::metrics::global());
    assert!(prom.contains("sim_rounds"), "{prom}");
    let rounds = hadar::obs::metrics::core().sim_rounds.get();
    assert_eq!(rounds, res.rounds, "sim.rounds counter matches the run");
    obs::reset();
}

#[test]
fn telemetry_is_valid_jsonl_one_record_per_round() {
    let _guard = test_lock();
    obs::reset();
    obs::set_enabled(false);
    let (res, text) = run_hadar_sim60();
    assert_eq!(text.lines().count() as u64, res.rounds,
               "one record per scheduling round");
    let mut last_round = None;
    for line in text.lines() {
        let v = hadar::util::json::parse(line).unwrap();
        assert_eq!(v.get("scheduler").as_str(), Some("hadar"));
        let round = v.get("round").as_u64().unwrap();
        if let Some(prev) = last_round {
            assert!(round > prev, "rounds strictly increase");
        }
        last_round = Some(round);
        assert!(v.get("now").as_f64().is_some());
        assert!(v.get("active_jobs").as_u64().is_some());
        assert!(v.get("gpus_allocated").as_u64().is_some());
        assert!(v.get("plan_changed").as_bool().is_some());
        // Non-timing streams must not leak wall-clock fields.
        assert!(v.get("sched_wall_secs").as_f64().is_none());
        // Hadar exposes solver counters in every record.
        assert!(v.get("solver").get("dp_rounds").as_u64().is_some(),
                "{line}");
    }
}
