//! Fixture tests for the `hadar lint` rule engine: every rule gets at
//! least one fixture proving it fires and one proving the masking layer
//! or a pragma suppresses it. The fixtures are small synthetic source
//! files pushed through [`hadar::analysis::rules::lint_file`] with a
//! hand-built [`SourceFile`], so each case pins one behaviour without
//! touching the real tree (that is `lint_selfaudit.rs`' job).

use hadar::analysis::modgraph::{self, FileClass, SourceFile};
use hadar::analysis::rules::{lint_file, FileLint};
use hadar::analysis::{lint_tree, rules};

/// Build a [`SourceFile`] fixture under the given module path; the
/// class is derived exactly as the module graph would.
fn fixture(rel: &str, module: &[&str], src: &str) -> SourceFile {
    let module: Vec<String> =
        module.iter().map(|s| s.to_string()).collect();
    let class = modgraph::classify(&module);
    SourceFile {
        rel: rel.to_string(),
        class,
        module,
        deps: Vec::new(),
        src: src.to_string(),
    }
}

/// Lint a fixture in a plan-path module (`sched::fixture`).
fn lint_plan(src: &str) -> FileLint {
    lint_file(&fixture("sched/fixture.rs", &["sched", "fixture"], src))
}

/// Lint a fixture in a harness module (`expt::fixture`).
fn lint_harness(src: &str) -> FileLint {
    lint_file(&fixture("expt/fixture.rs", &["expt", "fixture"], src))
}

/// Rule ids of the surviving findings, in report order.
fn ids(fl: &FileLint) -> Vec<&str> {
    fl.findings.iter().map(|f| f.rule.as_str()).collect()
}

// ------------------------------------------------------ float-total-cmp

#[test]
fn float_total_cmp_fires_on_code() {
    let fl = lint_plan(
        "fn f(xs: &mut Vec<f64>) {\n\
             xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["float-total-cmp"]);
    assert_eq!(fl.findings[0].line, 2);
}

#[test]
fn float_total_cmp_ignores_comments_and_strings() {
    // The two real comment-only mentions in the tree (the regression
    // notes in util/stats.rs and sched/hadar.rs) must never flag; this
    // fixture reproduces both shapes plus a string literal.
    let fl = lint_plan(
        "// the old partial_cmp comparator panicked on NaN\n\
         /* partial_cmp */\n\
         fn f(a: f64, b: f64) -> std::cmp::Ordering {\n\
             let _doc = \"partial_cmp\";\n\
             a.total_cmp(&b)\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

#[test]
fn float_total_cmp_fires_inside_tests_too() {
    let fl = lint_plan(
        "#[cfg(test)]\nmod tests {\n\
             fn f(a: f64, b: f64) -> bool {\n\
                 a.partial_cmp(&b).is_some()\n\
             }\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["float-total-cmp"]);
}

// -------------------------------------------------- unordered-iteration

#[test]
fn unordered_iteration_fires_on_hash_iteration_in_plan_path() {
    let fl = lint_plan(
        "use std::collections::HashMap;\n\
         fn f(m: &HashMap<u32, u32>) -> u32 {\n\
             let mut s = 0;\n\
             for (_, v) in m {\n\
                 s += v;\n\
             }\n\
             s + m.values().sum::<u32>()\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["unordered-iteration", "unordered-iteration"]);
    assert_eq!(fl.findings[0].line, 4);
    assert_eq!(fl.findings[1].line, 7);
}

#[test]
fn unordered_iteration_allows_keyed_probes() {
    // get/insert/remove/len on a HashMap are deterministic — exactly
    // the `none_rows` pattern in sched/hadar.rs.
    let fl = lint_plan(
        "use std::collections::HashMap;\n\
         fn f(m: &mut HashMap<u32, u32>, k: u32) -> Option<u32> {\n\
             m.insert(k, 1);\n\
             m.remove(&(k + 1));\n\
             let _ = m.len();\n\
             m.get(&k).copied()\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

#[test]
fn unordered_iteration_is_plan_path_only() {
    let src = "use std::collections::HashMap;\n\
               fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                   m.values().sum()\n\
               }\n";
    assert_eq!(ids(&lint_plan(src)), ["unordered-iteration"]);
    assert!(lint_harness(src).findings.is_empty());
    // A bench module under sched/ is harness too.
    let bench =
        fixture("sched/bench.rs", &["sched", "bench"], src);
    assert_eq!(bench.class, FileClass::Harness);
    assert!(lint_file(&bench).findings.is_empty());
}

#[test]
fn unordered_iteration_skips_cfg_test_blocks() {
    let fl = lint_plan(
        "use std::collections::HashMap;\n\
         #[cfg(test)]\nmod tests {\n\
             fn f(m: &HashMap<u32, u32>) -> u32 {\n\
                 m.values().sum()\n\
             }\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

// ----------------------------------------------------------- wall-clock

#[test]
fn wall_clock_fires_everywhere_but_the_timer_homes() {
    let src = "fn f() -> std::time::Instant {\n\
                   std::time::Instant::now()\n\
               }\n";
    assert_eq!(ids(&lint_plan(src)), ["wall-clock"]);
    assert_eq!(ids(&lint_harness(src)), ["wall-clock"]);
    let sys = "fn f() -> std::time::SystemTime {\n\
                   std::time::SystemTime::now()\n\
               }\n";
    assert_eq!(ids(&lint_harness(sys)), ["wall-clock"]);
}

#[test]
fn wall_clock_exempts_obs_and_util_log() {
    let src = "fn f() -> std::time::Instant {\n\
                   std::time::Instant::now()\n\
               }\n";
    let obs = fixture("obs/trace.rs", &["obs", "trace"], src);
    assert!(lint_file(&obs).findings.is_empty());
    let log = fixture("util/log.rs", &["util", "log"], src);
    assert!(lint_file(&log).findings.is_empty());
    // …but not the rest of util/.
    let stats = fixture("util/stats.rs", &["util", "stats"], src);
    assert_eq!(ids(&lint_file(&stats)), ["wall-clock"]);
}

#[test]
fn wall_clock_skips_cfg_test_blocks() {
    let fl = lint_plan(
        "#[cfg(test)]\nmod tests {\n\
             fn f() -> std::time::Instant {\n\
                 std::time::Instant::now()\n\
             }\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

// ----------------------------------------------------------- raw-thread

#[test]
fn raw_thread_fires_without_a_resolved_worker_count() {
    let fl = lint_plan(
        "fn f() {\n\
             std::thread::spawn(|| {});\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["raw-thread"]);
    let fl = lint_harness(
        "fn f(workers: usize) {\n\
             std::thread::scope(|s| { let _ = (s, workers); });\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["raw-thread"]);
}

#[test]
fn raw_thread_allows_threads_param_or_resolver_call() {
    // The two sanctioned shapes: the enclosing fn receives an explicit
    // `threads` count, or it resolves one itself.
    let fl = lint_plan(
        "fn f(threads: usize) {\n\
             std::thread::scope(|s| { let _ = (s, threads); });\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    let fl = lint_plan(
        "fn g() {\n\
             let n = crate::sched::resolve_plan_threads(0);\n\
             std::thread::spawn(move || n);\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

// ------------------------------------------------------ deprecated-shim

#[test]
fn deprecated_shim_fires_even_in_tests() {
    let fl = lint_plan(
        "#[deprecated(note = \"moved\")]\n\
         pub fn old() {}\n\
         #[cfg(test)]\nmod tests {\n\
             #[deprecated]\nfn older() {}\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["deprecated-shim", "deprecated-shim"]);
}

// ------------------------------------------------------------ no-unsafe

#[test]
fn no_unsafe_fires_on_blocks_and_fns() {
    let fl = lint_plan(
        "fn f() {\n\
             let x = [1u8];\n\
             let _ = unsafe { *x.as_ptr() };\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["no-unsafe"]);
    // Prose mentions never flag.
    let fl = lint_plan("// unsafe is banned here\nfn f() {}\n");
    assert!(fl.findings.is_empty());
}

// ----------------------------------------------------------- nondet-rng

#[test]
fn nondet_rng_fires_on_entropy_sources() {
    let fl = lint_plan(
        "fn f() {\n\
             let r = rand::thread_rng();\n\
             let s: std::collections::hash_map::RandomState =\n\
                 Default::default();\n\
             let _ = (r, s);\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["nondet-rng", "nondet-rng"]);
    // The seeded house RNG does not.
    let fl = lint_plan(
        "fn f() -> u64 {\n\
             crate::util::rng::Rng::new(42).next_u64()\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

// ------------------------------------------------------------- env-read

#[test]
fn env_read_fires_on_var_and_vars() {
    let fl = lint_plan(
        "fn f() -> usize {\n\
             let _ = std::env::var(\"HADAR_X\");\n\
             std::env::vars().count()\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["env-read", "env-read"]);
    // env::args (CLI argv) is not an environment read.
    let fl = lint_harness("fn f() -> usize { std::env::args().count() }\n");
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

#[test]
fn env_read_skips_cfg_test_blocks() {
    let fl = lint_plan(
        "#[cfg(test)]\nmod tests {\n\
             fn f() {\n\
                 let _ = std::env::var(\"HADAR_PLAN_THREADS\");\n\
             }\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

// -------------------------------------------------------------- pragmas

#[test]
fn standalone_pragma_covers_next_code_line() {
    let fl = lint_harness(
        "fn f() -> std::time::Instant {\n\
             // lint: allow(wall-clock, reason = \"fixture timer\")\n\
             std::time::Instant::now()\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    assert_eq!((fl.pragmas, fl.suppressed), (1, 1));
}

#[test]
fn standalone_pragma_skips_blank_and_comment_lines() {
    let fl = lint_harness(
        "fn f() -> std::time::Instant {\n\
             // lint: allow(wall-clock, reason = \"fixture timer\")\n\
             \n\
             // which is to say:\n\
             std::time::Instant::now()\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
}

#[test]
fn trailing_pragma_covers_its_own_line() {
    let fl = lint_harness(
        "fn f() -> std::time::Instant {\n\
             std::time::Instant::now() // lint: allow(wall-clock, reason = \"fixture timer\")\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    // …and only that line: a trailing pragma one line early is stale
    // and the site still fires.
    let fl = lint_harness(
        "fn f() -> std::time::Instant { // lint: allow(wall-clock, reason = \"wrong line\")\n\
             std::time::Instant::now()\n\
         }\n",
    );
    assert_eq!(ids(&fl), ["stale-pragma", "wall-clock"]);
}

#[test]
fn allow_file_pragma_covers_the_whole_file() {
    let fl = lint_harness(
        "// lint: allow-file(wall-clock, reason = \"bench fixture\")\n\
         fn f() -> f64 {\n\
             let t0 = std::time::Instant::now();\n\
             t0.elapsed().as_secs_f64() + seconds()\n\
         }\n\
         fn seconds() -> f64 {\n\
             let t1 = std::time::Instant::now();\n\
             t1.elapsed().as_secs_f64()\n\
         }\n",
    );
    assert!(fl.findings.is_empty(), "{:?}", fl.findings);
    assert_eq!((fl.pragmas, fl.suppressed), (1, 2));
}

#[test]
fn pragma_only_suppresses_its_own_rule() {
    let fl = lint_harness(
        "fn f() {\n\
             // lint: allow(wall-clock, reason = \"fixture\")\n\
             let _ = std::env::var(\"X\");\n\
         }\n",
    );
    // The env read survives and the mismatched pragma is stale.
    assert_eq!(ids(&fl), ["stale-pragma", "env-read"]);
}

#[test]
fn stale_pragma_is_reported() {
    let fl = lint_harness(
        "// lint: allow(wall-clock, reason = \"nothing left to cover\")\n\
         fn f() {}\n",
    );
    assert_eq!(ids(&fl), ["stale-pragma"]);
    assert_eq!(fl.findings[0].line, 1);
    assert_eq!((fl.pragmas, fl.suppressed), (1, 0));
}

#[test]
fn malformed_and_unknown_rule_pragmas_are_syntax_findings() {
    // No reason.
    let fl = lint_harness("// lint: allow(wall-clock)\nfn f() {}\n");
    assert_eq!(ids(&fl), ["pragma-syntax"]);
    // Empty reason.
    let fl = lint_harness(
        "// lint: allow(wall-clock, reason = \"\")\nfn f() {}\n",
    );
    assert_eq!(ids(&fl), ["pragma-syntax"]);
    // Unknown rule id.
    let fl = lint_harness(
        "// lint: allow(no-such-rule, reason = \"x\")\nfn f() {}\n",
    );
    assert_eq!(ids(&fl), ["pragma-syntax"]);
}

// ------------------------------------------------------------- lint_tree

/// Write a tiny crate to a scratch dir, lint it end-to-end, and check
/// the report and its JSON shape.
#[test]
fn lint_tree_end_to_end() {
    let root = std::env::temp_dir()
        .join(format!("hadar_lint_e2e_{}", std::process::id()));
    let sched = root.join("sched");
    std::fs::create_dir_all(&sched).unwrap();
    std::fs::write(
        root.join("lib.rs"),
        "pub mod sched;\npub mod util;\n",
    )
    .unwrap();
    std::fs::write(sched.join("mod.rs"), "pub mod solver;\n").unwrap();
    std::fs::write(
        sched.join("solver.rs"),
        "pub fn pick(xs: &mut Vec<f64>) {\n\
             xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
         }\n",
    )
    .unwrap();
    std::fs::write(
        root.join("util.rs"),
        "pub fn helper() -> u32 { crate::sched::SEED }\n",
    )
    .unwrap();

    let report = lint_tree(&root).unwrap();
    assert_eq!(report.files.len(), 4);
    assert!(!report.clean());
    assert_eq!(report.findings.len(), 1);
    let f = &report.findings[0];
    assert_eq!(
        (f.rule.as_str(), f.file.as_str(), f.line, f.class),
        ("float-total-cmp", "sched/solver.rs", 2, "plan-path"),
    );
    // Classification + dep edges surface in the file summaries.
    let util = report
        .files
        .iter()
        .find(|s| s.file == "util.rs")
        .unwrap();
    assert_eq!(util.class, "harness");
    assert_eq!(util.deps, ["sched"]);

    // JSON report: stable tool tag, the finding, and a dirty summary.
    let json = report.to_json().pretty();
    assert!(json.contains("hadar-lint"), "{json}");
    assert!(json.contains("float-total-cmp"), "{json}");
    assert!(json.contains("\"clean\": false"), "{json}");
    let text = report.render();
    assert!(text.contains("sched/solver.rs:2"), "{text}");
    assert!(text.contains("DIRTY"), "{text}");

    std::fs::remove_dir_all(&root).ok();
}

/// An unresolvable `mod` declaration is an infrastructure error, not a
/// finding — a lint run that silently skipped files would certify
/// nothing.
#[test]
fn lint_tree_rejects_unresolvable_mods() {
    let root = std::env::temp_dir()
        .join(format!("hadar_lint_badmod_{}", std::process::id()));
    std::fs::create_dir_all(&root).unwrap();
    std::fs::write(root.join("lib.rs"), "mod missing;\n").unwrap();
    let err = lint_tree(&root).unwrap_err();
    assert!(err.contains("mod missing"), "{err}");
    std::fs::remove_dir_all(&root).ok();
}

/// The catalog itself: ids are unique, and the per-rule scoping flags
/// the docs promise are what the engine ships.
#[test]
fn rule_catalog_is_consistent() {
    let mut ids: Vec<&str> =
        rules::RULES.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), rules::RULES.len());
    assert_eq!(rules::RULES.len(), 8);
    let by = |id: &str| rules::rule(id).unwrap();
    assert!(by("unordered-iteration").plan_path_only);
    assert!(!by("unordered-iteration").in_tests);
    assert!(!by("wall-clock").in_tests);
    assert!(!by("raw-thread").in_tests);
    assert!(!by("env-read").in_tests);
    assert!(by("float-total-cmp").in_tests);
    assert!(by("no-unsafe").in_tests);
    assert!(by("nondet-rng").in_tests);
    assert!(by("deprecated-shim").in_tests);
    assert!(rules::rule("no-such-rule").is_none());
}
