//! End-to-end tests for the `expt` sweep subsystem: grid expansion,
//! JSON round-trips, runner determinism across worker counts, and the
//! artifact/report pipeline.

use hadar::cluster::events::ChurnConfig;
use hadar::expt::artifact::{self, ScenarioRecord};
use hadar::expt::report;
use hadar::expt::runner;
use hadar::expt::spec::{ClusterRef, EventsRef, SweepSpec, WorkloadSpec};
use hadar::sim::engine::SimConfig;

/// A fast sweep: 2 schedulers x 2 seeds x 2 slots on the 6-GPU
/// motivational cluster with a tiny trace (8 scenarios, sub-second).
fn tiny_sweep() -> SweepSpec {
    SweepSpec {
        name: "tiny".into(),
        schedulers: vec!["yarn-cs".into(), "hadar".into()],
        clusters: vec![ClusterRef::Preset("motivational".into())],
        workloads: vec![WorkloadSpec::Trace {
            n_jobs: 6,
            max_gpus: 2,
            all_at_start: true,
            hours_scale: 0.05,
        }],
        slots_secs: vec![180.0, 360.0],
        seeds: vec![3, 4],
        events: vec![EventsRef::None],
        base: SimConfig::default(),
        telemetry: false,
    }
}

/// The tiny sweep with a seeded-churn events axis: maintenance-only (the
/// cluster always recovers, so every job completes) over one slot/seed.
fn churn_sweep() -> SweepSpec {
    let mut spec = tiny_sweep();
    spec.name = "tiny-churn".into();
    spec.schedulers = vec!["gavel".into(), "hadar".into()];
    spec.slots_secs = vec![360.0];
    spec.seeds = vec![3];
    spec.events = vec![EventsRef::Churn(ChurnConfig {
        seed: 5,
        mean_interval_secs: 600.0,
        min_down_secs: 300.0,
        max_down_secs: 900.0,
        leave_fraction: 0.0,
        horizon_secs: 2.0 * 3600.0,
    })];
    spec
}

#[test]
fn grid_expansion_is_the_full_cartesian_product() {
    let spec = tiny_sweep();
    let scenarios = spec.expand();
    assert_eq!(scenarios.len(), 2 * 2 * 2);
    assert_eq!(scenarios.len(), spec.n_scenarios());
    let mut ids: Vec<String> = scenarios.iter().map(|s| s.id()).collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n);
}

#[test]
fn spec_roundtrips_through_json_file_format() {
    let spec = tiny_sweep();
    let text = spec.to_json().pretty();
    let back = SweepSpec::parse(&text).unwrap();
    let ids_a: Vec<String> = spec.expand().iter().map(|s| s.id()).collect();
    let ids_b: Vec<String> = back.expand().iter().map(|s| s.id()).collect();
    assert_eq!(ids_a, ids_b);
}

#[test]
fn sweep_is_deterministic_across_worker_counts() {
    let spec = tiny_sweep();
    let r2 = runner::run_sweep(&spec, 2).unwrap();
    let r8 = runner::run_sweep(&spec, 8).unwrap();
    let rec2: Vec<ScenarioRecord> =
        r2.iter().map(ScenarioRecord::from_run).collect();
    let rec8: Vec<ScenarioRecord> =
        r8.iter().map(ScenarioRecord::from_run).collect();
    let a = artifact::canonical_jsonl(&rec2);
    let b = artifact::canonical_jsonl(&rec8);
    assert_eq!(a.lines().count(), spec.n_scenarios());
    assert_eq!(a, b, "2-worker and 8-worker sweeps must emit byte-identical \
                      canonical JSONL");
}

#[test]
fn artifacts_roundtrip_and_report_renders() {
    let spec = tiny_sweep();
    let results = runner::run_sweep(&spec, 0).unwrap();
    let records: Vec<ScenarioRecord> =
        results.iter().map(ScenarioRecord::from_run).collect();

    // JSONL round-trip (the re-aggregation path of `hadar sweep --from`).
    let text = artifact::to_jsonl(&records);
    let back = artifact::parse_jsonl(&text).unwrap();
    assert_eq!(back, records);

    // Every scenario completed its whole workload.
    for r in &records {
        assert_eq!(r.completed, 6, "{}", r.id);
        assert!(r.ttd > 0.0);
        assert!(r.gru > 0.0 && r.gru <= 1.0);
        assert!(r.jct_p50 <= r.jct_p90 && r.jct_p90 <= r.jct_p99);
        assert!(r.jct_min <= r.jct_p50 && r.jct_p99 <= r.jct_max + 1e-9);
    }

    // The comparison report covers both schedulers against the baseline.
    let out = report::render(&records, "yarn-cs");
    assert!(out.contains("hadar"));
    assert!(out.contains("yarn-cs"));
    assert!(out.contains("per-scheduler summary"));
}

#[test]
fn event_seed_sweeps_are_byte_identical_across_worker_counts() {
    // The churn generator expands per scenario from its own seed, so the
    // same event trace replays under every scheduler and worker count:
    // canonical JSONL must match byte for byte.
    let spec = churn_sweep();
    let r1 = runner::run_sweep(&spec, 1).unwrap();
    let r4 = runner::run_sweep(&spec, 4).unwrap();
    let rec1: Vec<ScenarioRecord> =
        r1.iter().map(ScenarioRecord::from_run).collect();
    let rec4: Vec<ScenarioRecord> =
        r4.iter().map(ScenarioRecord::from_run).collect();
    let a = artifact::canonical_jsonl(&rec1);
    let b = artifact::canonical_jsonl(&rec4);
    assert_eq!(a, b, "same event seed must give byte-identical sweeps");
    // The summaries carry the dynamic-cluster metrics.
    for r in &rec1 {
        assert_eq!(r.events, "churn-s5-i600-d300-900-l0-h7200");
        assert!(r.anu > 0.0 && r.anu <= 1.0 + 1e-9, "{}", r.id);
        assert_eq!(r.completed, 6, "{}: churn must not lose jobs", r.id);
    }
    // Both schedulers saw the identical trace, so the comparison report
    // groups them together.
    let out = report::render(&rec1, "gavel");
    assert!(out.contains("churn-s5-i600-d300-900-l0-h7200"), "{out}");
    assert!(out.contains("1.00x"), "baseline row present: {out}");
}

#[test]
fn hadare_on_sim60_fills_the_whole_multi_gpu_cluster() {
    // The PR-4 bugfix seen from the sweep surface: `hadare` on the
    // 15-node × 4-GPU `sim60` preset (reachable with `scheduler:
    // "hadare"` in any spec) drives whole-node gangs, so its GRU is no
    // longer capped at 15/60 of nominal capacity. This is the sweep-smoke
    // grid CI runs via examples/sweep_hadare.json.
    let spec = SweepSpec {
        name: "hadare-sim60".into(),
        schedulers: vec!["hadar".into(), "hadare".into()],
        clusters: vec![ClusterRef::Preset("sim60".into())],
        workloads: vec![WorkloadSpec::Trace {
            n_jobs: 30,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 0.1,
        }],
        slots_secs: vec![360.0],
        seeds: vec![7],
        events: vec![EventsRef::None],
        base: SimConfig {
            max_rounds: 50_000,
            ..Default::default()
        },
        telemetry: false,
    };
    let results = runner::run_sweep(&spec, 0).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.result.jct.len(), 30, "{}: all jobs complete",
                   r.spec.id());
    }
    let hadare = results
        .iter()
        .find(|r| r.spec.scheduler == "hadare")
        .unwrap();
    // Pre-fix, 45 of 60 GPUs idled: GRU could never exceed 0.25. With
    // whole-node gangs and an all-at-start backlog it starts near 1.0.
    assert!(hadare.result.gru > 0.25,
            "hadare gru {} still node-capped", hadare.result.gru);
}

#[test]
fn hadare_shared_on_big8_shares_nodes_on_the_same_trace() {
    // The partial-node tentpole seen from the sweep surface: on the
    // two-pool big-node preset (reachable with `cluster: "big8"`),
    // `hadare-shared` plans per-pool gangs — big nodes are shared
    // between parents and each pool runs at its own type's rate — while
    // `hadare` drives whole-node gangs at the cross-pool bottleneck.
    // This checks routing + occupancy + completion on the identical
    // trace; the CRU advantage itself is pinned by the engine-level
    // stranding test (`shared_gangs_unstrand_single_type_parents...`).
    // This is the sweep-smoke grid CI runs via examples/sweep_big8.json.
    let spec = SweepSpec {
        name: "hadare-big8".into(),
        schedulers: vec!["hadare".into(), "hadare-shared".into()],
        clusters: vec![ClusterRef::Preset("big8".into())],
        workloads: vec![WorkloadSpec::Trace {
            n_jobs: 12,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 0.1,
        }],
        slots_secs: vec![360.0],
        seeds: vec![7],
        events: vec![EventsRef::None],
        base: SimConfig {
            max_rounds: 50_000,
            ..Default::default()
        },
        telemetry: false,
    };
    let results = runner::run_sweep(&spec, 0).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(r.result.jct.len(), 12, "{}: all jobs complete",
                   r.spec.id());
    }
    let shared = results
        .iter()
        .find(|r| r.spec.scheduler == "hadare-shared")
        .unwrap();
    let whole = results
        .iter()
        .find(|r| r.spec.scheduler == "hadare")
        .unwrap();
    // While several parents are active, per-pool gangs book every GPU
    // (32) just like whole-node gangs, but as 4-GPU sub-gangs that can
    // pair two parents on one node.
    let r0 = &shared.result.timeline[0];
    let booked: usize = r0.jobs.values().map(|rj| rj.gpus).sum();
    assert_eq!(booked, 32, "shared round 0 books every GPU");
    assert!(shared.result.cru > 0.0 && shared.result.gru > 0.0);
    assert!(whole.result.cru > 0.0 && whole.result.gru > 0.0);
}

#[test]
fn figure_sweeps_reproduce_the_serial_grids() {
    // The refactored figures route through the parallel runner; their
    // specs must still describe the exact historical grids.
    let te = hadar::figures::trace_eval::sweep_spec(
        &hadar::figures::trace_eval::TraceEvalConfig::default(),
    );
    assert_eq!(te.n_scenarios(), 4); // four schedulers
    assert_eq!(te.base.max_rounds, 50_000);

    let ph = hadar::figures::physical::sweep_spec(360.0);
    assert_eq!(ph.n_scenarios(), 2 * 7 * 3);

    let sl = hadar::figures::slots::sweep_spec("hadare");
    assert_eq!(sl.n_scenarios(), 2 * 7 * 4);
}
