//! Integration: the full stack — scheduler → virtual cluster → PJRT real
//! training → consolidation → quality eval. Skipped when artifacts are
//! missing (run `make artifacts`).

use hadar::cluster::spec::ClusterSpec;
use hadar::exec::emulation::{
    run_hadare_emulation, run_scheduler_emulation, EmulationConfig,
};
use hadar::exec::quality::evaluate_quality;
use hadar::runtime::Manifest;
use hadar::sched::hadar::Hadar;
use hadar::sim::engine::SimConfig;
use hadar::trace::workload::physical_jobs;
use std::path::PathBuf;

fn manifest() -> Option<Manifest> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn fast_cfg() -> EmulationConfig {
    EmulationConfig {
        sim: SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 500,
            horizon: 1e7,
        },
        steps_scale: 0.004,
        max_real_steps_per_round: 6,
        lr: 0.1,
        seed: 42,
    }
}

#[test]
fn hadare_emulation_trains_real_models() {
    let Some(m) = manifest() else { return };
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
    let res = run_hadare_emulation(&jobs, &cluster, &m, &fast_cfg(), None)
        .expect("emulation runs");
    assert_eq!(res.models.len(), 3);
    assert!(res.total_real_steps > 0);
    for model in &res.models {
        assert!(model.real_steps > 0, "job {} trained", model.job);
        // Loss curve exists and the trend is downward.
        assert!(!model.losses.is_empty());
        let first = model.losses.first().unwrap().1;
        let last = model.losses.last().unwrap().1;
        assert!(last < first + 0.5,
                "loss should not explode: {first} -> {last}");
    }
    // Scheduling metrics are coherent.
    assert!(res.sim.ttd > 0.0);
    assert_eq!(res.sim.jct.len(), 3);
}

#[test]
fn hadar_emulation_and_quality_comparison() {
    let Some(m) = manifest() else { return };
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
    let cfg = fast_cfg();
    let forked =
        run_hadare_emulation(&jobs, &cluster, &m, &cfg, None).unwrap();
    let mut hadar = Hadar::new();
    let unforked =
        run_scheduler_emulation(&jobs, &mut hadar, &cluster, &m, &cfg)
            .unwrap();
    assert_eq!(unforked.models.len(), 3);
    // HadarE's virtual makespan beats Hadar's (Theorem 3's payoff).
    assert!(forked.sim.ttd <= unforked.sim.ttd * 1.05,
            "hadare {} vs hadar {}", forked.sim.ttd, unforked.sim.ttd);

    let pairs: Vec<_> = jobs.iter().map(|j| (j.id, j.model)).collect();
    let report = evaluate_quality(&pairs, &forked.models, &unforked.models,
                                  &m, cfg.seed, 777)
        .expect("quality eval");
    assert_eq!(report.rows.len(), 3);
    for row in &report.rows {
        assert!(row.forking.is_finite());
        assert!(row.no_forking.is_finite());
    }
}
