//! Opt-in 1M-job end-to-end streaming runs over the delta-driven round
//! pipeline (`cargo test -q --release -- --ignored stream_1m`; CI runs
//! them on `workflow_dispatch` only).
//!
//! These exist to catch accidental O(total-jobs)-per-round regressions:
//! with one million admitted jobs but only a few hundred active at any
//! instant, the indexed queue keeps each round's cost proportional to
//! the delta, so the whole run finishes in minutes. A full-scan
//! regression turns either test into an hours-long hang, which is a
//! much louder signal than a benchmark ratio drifting.
//!
//! This file is in the blocking `rustfmt --check` scope of the fmt CI
//! job — keep it formatted (the legacy hand-wrapped modules are not).

use hadar::cluster::gpu::GpuType;
use hadar::cluster::spec::ClusterSpec;
use hadar::jobs::job::{Job, JobId};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::by_name;
use hadar::sim::engine::{self, SimConfig};
use hadar::sim::hadare_engine;

const N_JOBS: usize = 1_000_000;

/// Tiny single-GPU jobs: each finishes well inside one slot, so the
/// steady-state active set stays at roughly `N_JOBS / span_rounds`
/// jobs — the regime the delta pipeline is built for.
fn tiny_job(i: usize, span_rounds: usize, slot_secs: f64) -> Job {
    let arrival = (i % span_rounds) as f64 * slot_secs;
    let mut j = Job::new(i as u64, DlModel::Lstm, arrival, 1, 1, 100);
    j.set_throughput(GpuType::V100, 50.0);
    j.set_throughput(GpuType::P100, 30.0);
    j.set_throughput(GpuType::K80, 10.0);
    j
}

#[test]
#[ignore = "1M-job streaming run; opt in with --ignored stream_1m"]
fn stream_1m_hadar_on_scaled_cluster() {
    // 192 nodes / 1536 GPUs; ~667 arrivals per slot over 1500 slots,
    // far below capacity, so the waiting set stays small.
    let cluster = ClusterSpec::scaled(64, 8);
    let cfg = SimConfig::default();
    let span_rounds = 1500usize;
    let mut queue = JobQueue::new();
    for i in 0..N_JOBS {
        queue.admit(tiny_job(i, span_rounds, cfg.slot_secs)).unwrap();
    }
    let mut sched = by_name("hadar").unwrap();
    let res = engine::run(&mut queue, sched.as_mut(), &cluster, &cfg, false);
    assert!(queue.all_complete(), "all 1M jobs must finish");
    assert_eq!(res.jct.len(), N_JOBS, "one JCT per admitted job");
    assert_eq!(res.preemptions, 0, "static cluster never preempts");
    assert!(res.rounds >= span_rounds as u64, "must span the arrival window");
    // Spot-check a late arrival actually waited for its arrival slot.
    let last = JobId((N_JOBS - 1) as u64);
    assert!(res.jct[&last] > 0.0);
}

#[test]
#[ignore = "1M-parent streaming run; opt in with --ignored stream_1m"]
fn stream_1m_hadare_single_copy() {
    // One copy per parent keeps the forked-job universe at 2M records;
    // the O(1) tracker/queue completion counters are what make the
    // per-round `all_complete` checks affordable at this scale.
    let cluster = ClusterSpec::scaled(64, 8);
    let cfg = SimConfig::default();
    let span_slots = 6000usize;
    let mut parents = Vec::with_capacity(N_JOBS);
    for i in 0..N_JOBS {
        parents.push(tiny_job(i, span_slots, cfg.slot_secs));
    }
    let res = hadare_engine::run(&parents, &cluster, &cfg, Some(1));
    assert_eq!(res.sim.jct.len(), N_JOBS, "one JCT per parent");
    assert!(res.sim.rounds >= span_slots as u64, "must span arrivals");
    assert_eq!(res.sim.finish_times.len(), N_JOBS);
}
