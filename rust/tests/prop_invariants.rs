//! Property-based invariants over the schedulers and engines (Theorem 1/2
//! supports + the constraints of problem P1), using the in-tree property
//! harness (`util::prop` — proptest substitute, see DESIGN.md).

use hadar::cluster::gpu::GpuType;
use hadar::cluster::spec::ClusterSpec;
use hadar::cluster::state::ClusterState;
use hadar::jobs::job::{Job, JobId};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::price::{PriceBounds, PriceTable};
use hadar::sched::{by_name, RoundCtx, Scheduler, SCHEDULER_NAMES};
use hadar::sim::engine::{self, SimConfig};
use hadar::util::prop::{check_no_shrink, Config};
use hadar::util::rng::Rng;

/// Random job set over the sim60 GPU types.
fn gen_jobs(rng: &mut Rng) -> Vec<Job> {
    let n = rng.range_u(1, 14) as usize;
    (0..n)
        .map(|i| {
            let w = [1usize, 1, 2, 2, 4, 8][rng.below(6) as usize];
            let epochs = rng.range_u(1, 12);
            let mut j = Job::new(i as u64, DlModel::Lstm,
                                 rng.range_f(0.0, 2000.0), w, epochs, 50);
            let base = rng.range_f(5.0, 80.0);
            j.set_throughput(GpuType::V100, base);
            j.set_throughput(GpuType::P100, base * rng.range_f(0.4, 0.9));
            j.set_throughput(GpuType::K80, base * rng.range_f(0.05, 0.4));
            j
        })
        .collect()
}

/// Every scheduler, every round: capacity (1d) and gang (1e) hold.
#[test]
fn prop_capacity_and_gang_constraints() {
    check_no_shrink(
        Config { cases: 40, seed: 0xA11 },
        gen_jobs,
        |jobs| {
            let cluster = ClusterSpec::motivational();
            for name in SCHEDULER_NAMES {
                let mut queue = JobQueue::new();
                for j in jobs {
                    let mut j = j.clone();
                    j.arrival = 0.0;
                    queue.admit(j);
                }
                let active = queue.active_at(0.0);
                let mut s = by_name(name).unwrap();
                let ctx = RoundCtx {
                    round: 0,
                    now: 0.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    cluster: &cluster,
                };
                let plan = s.schedule(&ctx);
                // Capacity: re-applying the plan into a fresh state must
                // never exceed any pool (allocate() panics otherwise).
                let mut state = ClusterState::new(&cluster);
                for (id, alloc) in &plan.allocations {
                    for a in alloc.assignments(*id) {
                        if a.count > state.free(a.node, a.gpu) {
                            return Err(format!(
                                "{name}: capacity violated at node {} {:?}",
                                a.node, a.gpu
                            ));
                        }
                        state.allocate(a);
                    }
                }
                // Gang all-or-nothing: W_j exactly, or nothing.
                for (id, alloc) in &plan.allocations {
                    let job = queue.get(*id).unwrap();
                    if alloc.total_gpus() != job.gpus_requested {
                        return Err(format!(
                            "{name}: job {} got {} of {}",
                            id,
                            alloc.total_gpus(),
                            job.gpus_requested
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The dual price function: monotone in γ, bounded by [U_min, U_max],
/// and α >= 1 (Theorem 2's constants are well-defined).
#[test]
fn prop_price_function_bounds() {
    check_no_shrink(
        Config { cases: 60, seed: 0xB22 },
        gen_jobs,
        |jobs| {
            let refs: Vec<&Job> = jobs.iter().collect();
            if refs.is_empty() {
                return Ok(());
            }
            let types = [GpuType::V100, GpuType::P100, GpuType::K80];
            let bounds = PriceBounds::from_jobs(&refs, &types, 1e6, 1.0);
            if bounds.alpha() < 1.0 {
                return Err(format!("alpha {} < 1", bounds.alpha()));
            }
            let table = PriceTable::new(bounds.clone());
            let cluster = ClusterSpec::motivational();
            let state = ClusterState::new(&cluster);
            for &(node, gpu, cap) in
                &[(0usize, GpuType::V100, 2usize), (1, GpuType::P100, 3),
                  (2, GpuType::K80, 1)]
            {
                let mut last = 0.0;
                for extra in 0..=cap {
                    let p = table.price(&state, node, gpu, extra);
                    if p < last {
                        return Err(format!("price not monotone at {gpu:?}"));
                    }
                    if extra == 0
                        && (p - bounds.u_min[&gpu]).abs() > 1e-9 * p
                    {
                        return Err("empty pool != U_min".into());
                    }
                    if extra == cap
                        && (p - bounds.u_max[&gpu]).abs() > 1e-9 * p
                    {
                        return Err("full pool != U_max".into());
                    }
                    last = p;
                }
            }
            Ok(())
        },
    );
}

/// Simulation conservation laws: every completed job did exactly its work;
/// completion times ordered after arrivals; GRU in [0,1]; busy time never
/// exceeds capacity.
#[test]
fn prop_simulation_conservation() {
    check_no_shrink(
        Config { cases: 15, seed: 0xC33 },
        gen_jobs,
        |jobs| {
            let cluster = ClusterSpec::sim60();
            for name in ["hadar", "gavel"] {
                let mut queue = JobQueue::new();
                for j in jobs {
                    let mut j = j.clone();
                    // Re-derive throughputs across sim60's types.
                    j.set_throughput(GpuType::V100,
                                     j.throughput_on(GpuType::V100));
                    queue.admit(j);
                }
                let mut s = by_name(name).unwrap();
                let cfg = SimConfig {
                    max_rounds: 3_000,
                    ..Default::default()
                };
                let res = engine::run(&mut queue, s.as_mut(), &cluster,
                                      &cfg, true);
                if !(0.0..=1.0 + 1e-9).contains(&res.gru) {
                    return Err(format!("{name}: gru {}", res.gru));
                }
                if !(0.0..=1.0 + 1e-9).contains(&res.cru) {
                    return Err(format!("{name}: cru {}", res.cru));
                }
                for rec in &res.timeline {
                    if rec.busy_gpu_secs > rec.avail_gpu_secs + 1e-6 {
                        return Err(format!("{name}: busy > capacity"));
                    }
                }
                for job in queue.iter() {
                    if let Some(f) = job.finish_time {
                        if f < job.arrival {
                            return Err(format!(
                                "{name}: {} finished before arrival",
                                job.id
                            ));
                        }
                        if job.progress < job.total_iters() - 1e-6 {
                            return Err(format!(
                                "{name}: {} marked done early", job.id
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Hadar's payoff rule: a scheduled allocation never mixes in a GPU type
/// with zero throughput for that job (it would stall the whole gang via
/// the bottleneck rule).
#[test]
fn prop_hadar_never_uses_zero_throughput_types() {
    check_no_shrink(
        Config { cases: 40, seed: 0xD44 },
        |rng: &mut Rng| {
            let mut jobs = gen_jobs(rng);
            // Knock out K80 support for half the jobs.
            for j in jobs.iter_mut() {
                if rng.f64() < 0.5 {
                    j.throughput.remove(&GpuType::K80);
                }
            }
            jobs
        },
        |jobs| {
            let cluster = ClusterSpec::motivational();
            let mut queue = JobQueue::new();
            for j in jobs {
                let mut j = j.clone();
                j.arrival = 0.0;
                queue.admit(j);
            }
            let active = queue.active_at(0.0);
            let mut s = by_name("hadar").unwrap();
            let ctx = RoundCtx {
                round: 0,
                now: 0.0,
                slot_secs: 360.0,
                horizon: 1e7,
                queue: &queue,
                active: &active,
                cluster: &cluster,
            };
            let plan = s.schedule(&ctx);
            for (id, alloc) in &plan.allocations {
                let job = queue.get(*id).unwrap();
                for g in alloc.gpu_types() {
                    if job.throughput_on(g) <= 0.0 {
                        return Err(format!(
                            "job {id} allocated unusable type {g:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// HadarE work conservation (Theorem 3 corollary) across random mixes:
/// while >= 1 parent is unfinished, no node idles except possibly in the
/// final round.
#[test]
fn prop_hadare_no_idle_nodes_before_last_round() {
    check_no_shrink(
        Config { cases: 20, seed: 0xE55 },
        |rng: &mut Rng| {
            let cluster = ClusterSpec::testbed5();
            let pairs = hadar::trace::workload::cluster_gpu_pcie(&cluster);
            let n = rng.range_u(1, 6) as usize;
            (0..n)
                .map(|i| {
                    let mut j = Job::new(i as u64, DlModel::MiMa, 0.0, 1,
                                         rng.range_u(5, 40), 100);
                    j.throughput = hadar::jobs::throughput::throughput_row(
                        DlModel::MiMa, &pairs);
                    j
                })
                .collect::<Vec<Job>>()
        },
        |jobs| {
            let cluster = ClusterSpec::testbed5();
            let cfg = SimConfig {
                slot_secs: 90.0,
                restart_overhead: 10.0,
                max_rounds: 3_000,
                horizon: 1e7,
            };
            let res = hadar::sim::run_hadare(jobs, &cluster, &cfg, None);
            let n_nodes = cluster.nodes.len();
            for (i, rec) in res.sim.timeline.iter().enumerate() {
                let nodes_busy: usize =
                    rec.jobs.values().map(|rj| rj.gpus).sum();
                let is_last = i + 1 == res.sim.timeline.len();
                if !is_last && nodes_busy < n_nodes {
                    return Err(format!(
                        "round {i}: {nodes_busy}/{n_nodes} nodes busy"
                    ));
                }
            }
            Ok(())
        },
    );
}
