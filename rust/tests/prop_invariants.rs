//! Property-based invariants over the schedulers and engines (Theorem 1/2
//! supports + the constraints of problem P1), using the in-tree property
//! harness (`util::prop` — proptest substitute, see DESIGN.md).

use hadar::cluster::gpu::GpuType;
use hadar::cluster::spec::ClusterSpec;
use hadar::cluster::state::{Assignment, ClusterState};
use hadar::jobs::job::{Job, JobId};
use hadar::jobs::model::DlModel;
use hadar::jobs::queue::JobQueue;
use hadar::sched::price::{PriceBounds, PriceTable};
use hadar::sched::{by_name, RoundCtx, Scheduler, SCHEDULER_NAMES};
use hadar::sim::engine::{self, SimConfig};
use hadar::util::prop::{check_no_shrink, Config};
use hadar::util::rng::Rng;

/// Random job set over the sim60 GPU types.
fn gen_jobs(rng: &mut Rng) -> Vec<Job> {
    let n = rng.range_u(1, 14) as usize;
    (0..n)
        .map(|i| {
            let w = [1usize, 1, 2, 2, 4, 8][rng.below(6) as usize];
            let epochs = rng.range_u(1, 12);
            let mut j = Job::new(i as u64, DlModel::Lstm,
                                 rng.range_f(0.0, 2000.0), w, epochs, 50);
            let base = rng.range_f(5.0, 80.0);
            j.set_throughput(GpuType::V100, base);
            j.set_throughput(GpuType::P100, base * rng.range_f(0.4, 0.9));
            j.set_throughput(GpuType::K80, base * rng.range_f(0.05, 0.4));
            j
        })
        .collect()
}

/// Every scheduler, every round: capacity (1d) and gang (1e) hold.
#[test]
fn prop_capacity_and_gang_constraints() {
    check_no_shrink(
        Config { cases: 40, seed: 0xA11 },
        gen_jobs,
        |jobs| {
            let cluster = ClusterSpec::motivational();
            for name in SCHEDULER_NAMES {
                let mut queue = JobQueue::new();
                for j in jobs {
                    let mut j = j.clone();
                    j.arrival = 0.0;
                    queue.admit(j).unwrap();
                }
                let active = queue.active_at(0.0);
                let mut s = by_name(name).unwrap();
                let ctx = RoundCtx {
                    round: 0,
                    now: 0.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    delta: None,
                    cluster: &cluster,
                };
                let plan = s.schedule(&ctx);
                // Capacity: re-applying the plan into a fresh state must
                // never exceed any pool (allocate() panics otherwise).
                let mut state = ClusterState::new(&cluster);
                for (id, alloc) in &plan.allocations {
                    for a in alloc.assignments(*id) {
                        if a.count > state.free(a.node, a.gpu) {
                            return Err(format!(
                                "{name}: capacity violated at node {} {:?}",
                                a.node, a.gpu
                            ));
                        }
                        state.allocate(a);
                    }
                }
                // Gang all-or-nothing: W_j exactly, or nothing.
                for (id, alloc) in &plan.allocations {
                    let job = queue.get(*id).unwrap();
                    if alloc.total_gpus() != job.gpus_requested {
                        return Err(format!(
                            "{name}: job {} got {} of {}",
                            id,
                            alloc.total_gpus(),
                            job.gpus_requested
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The dual price function: monotone in γ, bounded by [U_min, U_max],
/// and α >= 1 (Theorem 2's constants are well-defined).
#[test]
fn prop_price_function_bounds() {
    check_no_shrink(
        Config { cases: 60, seed: 0xB22 },
        gen_jobs,
        |jobs| {
            let refs: Vec<&Job> = jobs.iter().collect();
            if refs.is_empty() {
                return Ok(());
            }
            let types = [GpuType::V100, GpuType::P100, GpuType::K80];
            let bounds = PriceBounds::from_jobs(&refs, &types, 1e6, 1.0);
            if bounds.alpha() < 1.0 {
                return Err(format!("alpha {} < 1", bounds.alpha()));
            }
            let table = PriceTable::new(bounds.clone());
            let cluster = ClusterSpec::motivational();
            let state = ClusterState::new(&cluster);
            for &(node, gpu, cap) in
                &[(0usize, GpuType::V100, 2usize), (1, GpuType::P100, 3),
                  (2, GpuType::K80, 1)]
            {
                let mut last = 0.0;
                for extra in 0..=cap {
                    let p = table.price(&state, node, gpu, extra);
                    if p < last {
                        return Err(format!("price not monotone at {gpu:?}"));
                    }
                    if extra == 0
                        && (p - bounds.u_min[&gpu]).abs() > 1e-9 * p
                    {
                        return Err("empty pool != U_min".into());
                    }
                    if extra == cap
                        && (p - bounds.u_max[&gpu]).abs() > 1e-9 * p
                    {
                        return Err("full pool != U_max".into());
                    }
                    last = p;
                }
            }
            Ok(())
        },
    );
}

/// Simulation conservation laws: every completed job did exactly its work;
/// completion times ordered after arrivals; GRU in [0,1]; busy time never
/// exceeds capacity.
#[test]
fn prop_simulation_conservation() {
    check_no_shrink(
        Config { cases: 15, seed: 0xC33 },
        gen_jobs,
        |jobs| {
            let cluster = ClusterSpec::sim60();
            for name in ["hadar", "gavel"] {
                let mut queue = JobQueue::new();
                for j in jobs {
                    let mut j = j.clone();
                    // Re-derive throughputs across sim60's types.
                    j.set_throughput(GpuType::V100,
                                     j.throughput_on(GpuType::V100));
                    queue.admit(j).unwrap();
                }
                let mut s = by_name(name).unwrap();
                let cfg = SimConfig {
                    max_rounds: 3_000,
                    ..Default::default()
                };
                let res = engine::run(&mut queue, s.as_mut(), &cluster,
                                      &cfg, true);
                if !(0.0..=1.0 + 1e-9).contains(&res.gru) {
                    return Err(format!("{name}: gru {}", res.gru));
                }
                if !(0.0..=1.0 + 1e-9).contains(&res.cru) {
                    return Err(format!("{name}: cru {}", res.cru));
                }
                for rec in &res.timeline {
                    if rec.busy_gpu_secs > rec.avail_gpu_secs + 1e-6 {
                        return Err(format!("{name}: busy > capacity"));
                    }
                }
                for job in queue.iter() {
                    if let Some(f) = job.finish_time {
                        if f < job.arrival {
                            return Err(format!(
                                "{name}: {} finished before arrival",
                                job.id
                            ));
                        }
                        if job.progress < job.total_iters() - 1e-6 {
                            return Err(format!(
                                "{name}: {} marked done early", job.id
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Hadar's payoff rule: a scheduled allocation never mixes in a GPU type
/// with zero throughput for that job (it would stall the whole gang via
/// the bottleneck rule).
#[test]
fn prop_hadar_never_uses_zero_throughput_types() {
    check_no_shrink(
        Config { cases: 40, seed: 0xD44 },
        |rng: &mut Rng| {
            let mut jobs = gen_jobs(rng);
            // Knock out K80 support for half the jobs.
            for j in jobs.iter_mut() {
                if rng.f64() < 0.5 {
                    j.throughput.remove(&GpuType::K80);
                }
            }
            jobs
        },
        |jobs| {
            let cluster = ClusterSpec::motivational();
            let mut queue = JobQueue::new();
            for j in jobs {
                let mut j = j.clone();
                j.arrival = 0.0;
                queue.admit(j).unwrap();
            }
            let active = queue.active_at(0.0);
            let mut s = by_name("hadar").unwrap();
            let ctx = RoundCtx {
                round: 0,
                now: 0.0,
                slot_secs: 360.0,
                horizon: 1e7,
                queue: &queue,
                active: &active,
                delta: None,
                cluster: &cluster,
            };
            let plan = s.schedule(&ctx);
            for (id, alloc) in &plan.allocations {
                let job = queue.get(*id).unwrap();
                for g in alloc.gpu_types() {
                    if job.throughput_on(g) <= 0.0 {
                        return Err(format!(
                            "job {id} allocated unusable type {g:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Everything observable about a [`ClusterState`]: the rolling digest, the
/// totals, every pool's free count, the assignment log, and the per-type
/// free-slot index iteration order.
#[allow(clippy::type_complexity)]
fn state_fingerprint(
    s: &ClusterState,
) -> (u64, usize, Vec<usize>, Vec<Assignment>, Vec<Vec<(usize, usize)>>) {
    let mut frees = Vec::new();
    for h in 0..s.n_nodes() {
        for &g in &GpuType::ALL {
            frees.push(s.free(h, g));
        }
    }
    let index: Vec<Vec<(usize, usize)>> = GpuType::ALL
        .iter()
        .map(|&g| s.free_slots_of_type(g).collect())
        .collect();
    (s.digest(), s.total_free(), frees, s.assignments().to_vec(), index)
}

/// Allocate a random feasible assignment, if any pool has room.
fn random_alloc(rng: &mut Rng, s: &mut ClusterState) {
    let slots = s.free_slots();
    if slots.is_empty() {
        return;
    }
    let &(h, g, free) = rng.choice(&slots);
    let count = rng.range_u(1, free as u64) as usize;
    let job = JobId(rng.below(5));
    s.allocate(Assignment { job, node: h, gpu: g, count });
}

/// Allocate/undo round-trips leave the state bit-identical: digest, free
/// counts, totals, assignment log, and slot-index order all restore after
/// `rewind`, after `release_job`, and after draining everything — across
/// random clusters and random allocate/release/rewind walks. Also pins the
/// incrementally maintained slot index to a from-scratch rebuild at every
/// step (the zero-clone solver's correctness rests on both).
#[test]
fn prop_allocate_undo_round_trips_state() {
    check_no_shrink(
        Config { cases: 50, seed: 0xF66 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let cluster = match rng.below(3) {
                0 => ClusterSpec::motivational(),
                1 => ClusterSpec::sim60(),
                _ => ClusterSpec::scaled(3, 2),
            };
            let mut s = ClusterState::new(&cluster);
            let fresh = state_fingerprint(&s);
            for _ in 0..30 {
                match rng.below(3) {
                    0 => random_alloc(&mut rng, &mut s),
                    1 => {
                        let _ = s.release_job(JobId(rng.below(5)));
                    }
                    _ => {
                        // Checkpoint, a burst of allocations, rewind: the
                        // DP's select-branch pattern must restore exactly.
                        let before = state_fingerprint(&s);
                        let mark = s.checkpoint();
                        for _ in 0..rng.range_u(1, 4) {
                            random_alloc(&mut rng, &mut s);
                        }
                        s.rewind(mark);
                        if state_fingerprint(&s) != before {
                            return Err("rewind did not restore".into());
                        }
                    }
                }
                // The slot index must always match a from-scratch rebuild
                // (stable sort by free desc == node asc within ties).
                for &g in &GpuType::ALL {
                    let got: Vec<(usize, usize)> =
                        s.free_slots_of_type(g).collect();
                    let mut want: Vec<(usize, usize)> = (0..s.n_nodes())
                        .map(|h| (h, s.free(h, g)))
                        .filter(|&(_, f)| f > 0)
                        .collect();
                    want.sort_by(|a, b| b.1.cmp(&a.1));
                    if got != want {
                        return Err(format!("slot index drifted for {g:?}"));
                    }
                }
            }
            for j in 0..5 {
                s.release_job(JobId(j));
            }
            if state_fingerprint(&s) != fresh {
                return Err("drained state differs from fresh".into());
            }
            Ok(())
        },
    );
}

/// HadarE work conservation (Theorem 3 corollary) across random mixes:
/// while >= 1 parent is unfinished, no node idles except possibly in the
/// final round.
#[test]
fn prop_hadare_no_idle_nodes_before_last_round() {
    check_no_shrink(
        Config { cases: 20, seed: 0xE55 },
        |rng: &mut Rng| {
            let cluster = ClusterSpec::testbed5();
            let pairs = hadar::trace::workload::cluster_gpu_pcie(&cluster);
            let n = rng.range_u(1, 6) as usize;
            (0..n)
                .map(|i| {
                    let mut j = Job::new(i as u64, DlModel::MiMa, 0.0, 1,
                                         rng.range_u(5, 40), 100);
                    j.throughput = hadar::jobs::throughput::throughput_row(
                        DlModel::MiMa, &pairs);
                    j
                })
                .collect::<Vec<Job>>()
        },
        |jobs| {
            let cluster = ClusterSpec::testbed5();
            let cfg = SimConfig {
                slot_secs: 90.0,
                restart_overhead: 10.0,
                max_rounds: 3_000,
                horizon: 1e7,
            };
            let res = hadar::sim::run_hadare(jobs, &cluster, &cfg, None);
            let n_nodes = cluster.nodes.len();
            for (i, rec) in res.sim.timeline.iter().enumerate() {
                let nodes_busy: usize =
                    rec.jobs.values().map(|rj| rj.gpus).sum();
                let is_last = i + 1 == res.sim.timeline.len();
                if !is_last && nodes_busy < n_nodes {
                    return Err(format!(
                        "round {i}: {nodes_busy}/{n_nodes} nodes busy"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The incremental waiting/arrival indexes inside [`JobQueue`] always
/// agree with a from-scratch rebuild of the same state, after arbitrary
/// interleavings of `admit` / `poll_round` / `complete` /
/// `note_preempted` — including late admissions behind the watermark,
/// non-monotone poll times, double completions, and preemptions of
/// jobs that never arrived. The oracle is a plain model: a list of
/// `(id, arrival)` pairs plus a drained set and a completed set,
/// updated by the obvious O(n) logic.
#[test]
fn prop_queue_indexes_agree_with_rebuild() {
    check_no_shrink(
        Config { cases: 60, seed: 0x1DE7 },
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let mut q = JobQueue::new();

            // The model.
            let mut admitted: Vec<(JobId, f64)> = Vec::new();
            let mut drained: std::collections::BTreeSet<JobId> =
                Default::default();
            let mut done: std::collections::BTreeSet<JobId> =
                Default::default();
            let mut exp_completions: Vec<JobId> = Vec::new();
            let mut exp_preemptions: Vec<JobId> = Vec::new();
            let mut watermark = f64::NEG_INFINITY;

            let mut next_id = 0u64;
            let mut now = 0.0f64;
            let ops = rng.range_u(20, 60);
            for op in 0..ops {
                match rng.below(4) {
                    0 => {
                        // Admit a small batch, arrivals both behind and
                        // ahead of the watermark.
                        for _ in 0..rng.range_u(1, 4) {
                            let arrival = rng.range_f(0.0, now + 500.0);
                            let j = Job::new(next_id, DlModel::Lstm,
                                             arrival, 1, 1, 10);
                            q.admit(j).unwrap();
                            admitted.push((JobId(next_id), arrival));
                            next_id += 1;
                        }
                    }
                    1 => {
                        // Poll; a quarter of the polls go backwards in
                        // time (the watermark must stay monotone).
                        let t = if rng.below(4) == 0 && now > 0.0 {
                            rng.range_f(0.0, now)
                        } else {
                            now + rng.range_f(0.0, 200.0)
                        };
                        now = now.max(t);
                        let delta = q.poll_round(t);
                        watermark = watermark.max(t);
                        // Oracle arrivals: admitted, not yet drained,
                        // not completed, arrival within the watermark —
                        // in (arrival, id) order, like the index.
                        let mut want: Vec<(JobId, f64)> = admitted
                            .iter()
                            .filter(|(id, a)| {
                                *a <= watermark
                                    && !drained.contains(id)
                                    && !done.contains(id)
                            })
                            .copied()
                            .collect();
                        want.sort_by(|x, y| {
                            x.1.partial_cmp(&y.1).unwrap()
                                .then(x.0.cmp(&y.0))
                        });
                        let want: Vec<JobId> =
                            want.into_iter().map(|(id, _)| id).collect();
                        if delta.arrivals != want {
                            return Err(format!(
                                "op {op}: poll({t}) arrivals {:?} != \
                                 oracle {:?}",
                                delta.arrivals, want));
                        }
                        for id in &delta.arrivals {
                            drained.insert(*id);
                        }
                        if delta.completions != exp_completions {
                            return Err(format!(
                                "op {op}: delta completions {:?} != \
                                 buffered {:?}",
                                delta.completions, exp_completions));
                        }
                        if delta.preemptions != exp_preemptions {
                            return Err(format!(
                                "op {op}: delta preemptions {:?} != \
                                 buffered {:?}",
                                delta.preemptions, exp_preemptions));
                        }
                        if delta.events != 0 {
                            return Err("poll stamped events".into());
                        }
                        exp_completions.clear();
                        exp_preemptions.clear();
                    }
                    2 => {
                        // Complete a random id: known or unknown,
                        // possibly already completed, possibly not yet
                        // arrived (an admission cancelled early).
                        let id = JobId(rng.below(next_id.max(1) + 2));
                        let known = admitted.iter()
                            .any(|&(j, _)| j == id);
                        let expect = known && !done.contains(&id);
                        if q.complete(id, now) != expect {
                            return Err(format!(
                                "op {op}: complete({id:?}) returned \
                                 {}", !expect));
                        }
                        if expect {
                            done.insert(id);
                            drained.remove(&id);
                            exp_completions.push(id);
                        }
                    }
                    _ => {
                        // Preempt a random id; only members of the
                        // waiting set may surface in the delta.
                        let id = JobId(rng.below(next_id.max(1) + 2));
                        q.note_preempted(id);
                        if drained.contains(&id) {
                            exp_preemptions.push(id);
                        }
                    }
                }

                // Waiting set == drained minus completed, in id order
                // (the model removes completions from `drained`).
                let want: Vec<JobId> = drained.iter().copied().collect();
                if q.waiting() != want {
                    return Err(format!(
                        "op {op}: waiting() {:?} != rebuild {:?}",
                        q.waiting(), want));
                }
                if q.waiting_len() != want.len() {
                    return Err(format!("op {op}: waiting_len mismatch"));
                }
                if q.all_complete() != (done.len() == admitted.len()) {
                    return Err(format!(
                        "op {op}: all_complete() {} != scan {}",
                        q.all_complete(), done.len() == admitted.len()));
                }

                // Arrival probes on both sides of the watermark hit the
                // index path and the fallback scan; both must agree
                // with the O(n) fold over non-completed arrivals.
                let probes = [now + rng.range_f(0.0, 300.0),
                              rng.range_f(-1.0, now.max(0.0))];
                for probe in probes {
                    let want = admitted
                        .iter()
                        .filter(|(id, a)| {
                            *a > probe && !done.contains(id)
                        })
                        .map(|&(_, a)| a)
                        .fold(None, |acc: Option<f64>, a| {
                            Some(acc.map_or(a, |b| b.min(a)))
                        });
                    if q.next_arrival_after(probe) != want {
                        return Err(format!(
                            "op {op}: next_arrival_after({probe}) \
                             {:?} != oracle {:?}",
                            q.next_arrival_after(probe), want));
                    }
                }
            }
            Ok(())
        },
    );
}
