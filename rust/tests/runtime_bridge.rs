//! Integration: the python-AOT -> rust-PJRT bridge. Requires
//! `make artifacts` (tests are skipped gracefully if artifacts are absent,
//! so `cargo test` stays green on a fresh checkout).

use hadar::runtime::{
    consolidate_states, flatten_params, Manifest, Runtime, Trainer,
};
use hadar::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> Option<Manifest> {
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).expect("manifest parses"))
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn train_step_runs_and_loss_decreases() {
    let Some(m) = manifest() else { return };
    let v = m.variant("tiny").expect("tiny variant");
    let rt = Runtime::cpu().expect("pjrt cpu");
    let exe = rt.load_train(v).expect("compile train hlo");
    let state = rt.init_state(v, 42);
    let mut trainer = Trainer::new(state, v.vocab, 42, 0.1);

    let first = trainer.run_steps(&exe, 1).expect("first step");
    // Untrained CE should be near log(vocab) = log(256) ≈ 5.55.
    assert!((first - (v.vocab as f32).ln()).abs() < 1.0,
            "initial loss {first} far from log(vocab)");
    let last = trainer.run_steps(&exe, 30).expect("more steps");
    assert!(last < first - 0.5,
            "loss should fall: {first} -> {last}");
    assert_eq!(trainer.steps_done, 31);
    assert_eq!(trainer.losses.len(), 31);
}

#[test]
fn eval_step_reports_loss_and_accuracy() {
    let Some(m) = manifest() else { return };
    let v = m.variant("tiny").expect("tiny variant");
    let rt = Runtime::cpu().expect("pjrt cpu");
    let train = rt.load_train(v).expect("train");
    let eval = rt.load_eval(v).expect("eval");
    let state = rt.init_state(v, 7);
    let mut trainer = Trainer::new(state, v.vocab, 7, 0.1);
    let mut rng = Rng::new(99);

    let tokens = trainer.corpus.batch(&mut rng, v.batch, v.seq + 1);
    let (l0, a0) = eval
        .eval(&trainer.state, &tokens, v.batch, v.seq + 1)
        .expect("eval before");
    trainer.run_steps(&train, 40).expect("train");
    let (l1, a1) = eval
        .eval(&trainer.state, &tokens, v.batch, v.seq + 1)
        .expect("eval after");
    assert!(l1 < l0, "eval loss should fall: {l0} -> {l1}");
    assert!(a1 > a0, "accuracy should rise: {a0} -> {a1}");
    assert!((0.0..=1.0).contains(&a1));
}

#[test]
fn deterministic_given_same_seed() {
    let Some(m) = manifest() else { return };
    let v = m.variant("tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_train(v).unwrap();
    let run = |seed: u64| -> f32 {
        let mut t = Trainer::new(rt.init_state(v, seed), v.vocab, seed, 0.1);
        t.run_steps(&exe, 5).unwrap()
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn consolidation_preserves_shapes_and_averages() {
    let Some(m) = manifest() else { return };
    let v = m.variant("tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_train(v).unwrap();
    // Two copies from the same init, trained on different streams.
    let mut a = Trainer::new(rt.init_state(v, 1), v.vocab, 10, 0.05);
    let mut b = Trainer::new(rt.init_state(v, 1), v.vocab, 20, 0.05);
    a.run_steps(&exe, 3).unwrap();
    b.run_steps(&exe, 3).unwrap();
    let avg = consolidate_states(&[&a.state, &b.state], &[1.0, 1.0], v)
        .expect("consolidate");
    let fa = flatten_params(&a.state.params).unwrap();
    let fb = flatten_params(&b.state.params).unwrap();
    let favg = flatten_params(&avg).unwrap();
    assert_eq!(favg.len(), fa.len());
    for i in (0..favg.len()).step_by(1000) {
        let expect = (fa[i] + fb[i]) / 2.0;
        assert!((favg[i] - expect).abs() < 1e-6);
    }
}
