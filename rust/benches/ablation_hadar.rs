//! Ablations over Hadar's design knobs (DESIGN.md §Key design decisions):
//!
//! * DP vs payoff-density greedy (the dp_job_cap switch);
//! * communication-cost factor for spread allocations;
//! * price-scale η (Theorem 2's D_0 <= OPT/2 knob);
//! * incremental vs full re-scheduling.
//!
//! Run: `cargo bench --bench ablation_hadar`

use hadar::cluster::spec::ClusterSpec;
use hadar::jobs::queue::JobQueue;
use hadar::sched::hadar::{Hadar, HadarConfig};
use hadar::sim::engine::{self, SimConfig};
use hadar::trace::philly::{generate, TraceConfig};
use hadar::trace::workload::materialize;
use hadar::util::bench::{section, Bencher};
use hadar::util::table::Table;

fn run_with(cfg: HadarConfig, n_jobs: usize) -> (f64, f64, f64) {
    let cluster = ClusterSpec::sim60();
    let trace = generate(&TraceConfig {
        n_jobs,
        seed: 5,
        all_at_start: true,
        max_gpus: 8,
        ..Default::default()
    });
    let mut jobs = materialize(&trace, &cluster, 5);
    for j in &mut jobs {
        j.epochs = (j.epochs / 4).max(1); // keep the ablation quick
    }
    let mut queue = JobQueue::new();
    for j in jobs {
        queue.admit(j).unwrap();
    }
    let mut hadar = Hadar::with_config(cfg);
    let res = engine::run(&mut queue, &mut hadar, &cluster,
                          &SimConfig::default(), false);
    (res.ttd, res.gru, res.sched_wall_per_round * 1e3)
}

fn main() {
    section("Ablation — Hadar design knobs (120-job trace, sim60)");

    let base = HadarConfig::default();
    let mut t = Table::new(&["variant", "TTD (s)", "GRU", "sched ms/round"]);
    let mut add = |name: &str, cfg: HadarConfig| {
        let (ttd, gru, ms) = Bencher::new(&format!("ablation_{name}"))
            .warmup(0)
            .iters(1)
            .run(|| run_with(cfg, 120));
        t.row(&[
            name.to_string(),
            format!("{ttd:.0}"),
            format!("{:.1}%", gru * 100.0),
            format!("{ms:.2}"),
        ]);
    };

    add("baseline", base);
    add("dp_always(greedy_off)", HadarConfig { dp_job_cap: 0, ..base });
    add("comm_factor=0", HadarConfig { comm_factor: 0.0, ..base });
    add("comm_factor=0.5", HadarConfig { comm_factor: 0.5, ..base });
    add("eta=4", HadarConfig { eta: 4.0, ..base });
    add("eta=0.25", HadarConfig { eta: 0.25, ..base });
    add("incremental", HadarConfig { incremental: true, ..base });
    println!("{}", t.render());
    println!(
        "notes: dp_job_cap=0 forces the greedy path for every queue size; \
         comm_factor sweeps the spread-allocation penalty of Algorithm 2 \
         line 27; eta scales U_min (Eq. 7)."
    );
}
