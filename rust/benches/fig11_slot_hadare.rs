//! Bench: Fig. 11 — HadarE's CRU vs slot time {90,180,360,720}s over the
//! workload mixes on both clusters.
//! Run: `cargo bench --bench fig11_slot_hadare`

use hadar::figures::slots;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 11 — HadarE CRU vs slot time");
    let s = Bencher::new("fig11_sweep")
        .warmup(0)
        .iters(1)
        .run(|| slots::run("hadare"));
    println!("{}", slots::render(&s));
}
