//! Bench: Fig. 10 — average job completion time (with min/max ranges) of
//! Gavel/Hadar/HadarE across the seven workload mixes on both clusters.
//! Run: `cargo bench --bench fig10_jct`

use hadar::figures::physical;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 10 — JCT across workload mixes (aws5 + testbed5)");
    let p = Bencher::new("fig10_grid")
        .warmup(0)
        .iters(1)
        .run(|| physical::run(360.0));
    println!("{}", physical::render_fig10(&p));
}
