//! Microbenchmarks of the L3 scheduler hot path (the §Perf targets):
//! the zero-clone Hadar solver vs the frozen pre-optimisation reference
//! on both solve paths (exact DP at queue ≤ `dp_job_cap`, payoff-density
//! greedy at 100-1000 jobs) over `sim60` and the ~256-node synthetic
//! cluster, plus raw Hadar decision latency and the HadarE round planner.
//!
//! The comparison section is the bench behind the ≥5x DP-path claim in
//! `docs/performance.md`; the same suite is exported as a JSON artifact by
//! `hadar bench --json` (BENCH_sched.json).
//!
//! Run: `cargo bench --bench l3_sched_micro`

use hadar::cluster::spec::ClusterSpec;
use hadar::forking::forker::ForkIds;
use hadar::forking::tracker::JobTracker;
use hadar::jobs::queue::JobQueue;
use hadar::sched::bench as schedbench;
use hadar::sched::hadar::{Hadar, HadarConfig};
use hadar::sched::hadare::HadarE;
use hadar::sched::{RoundCtx, Scheduler};
use hadar::trace::philly::{generate, TraceConfig};
use hadar::trace::workload::{materialize, physical_jobs};
use hadar::util::bench::{section, Bencher};

fn main() {
    section("L3 microbench — reference vs zero-clone solver");
    let results = schedbench::run_suite(false);
    print!("{}", schedbench::render(&results));
    for r in &results {
        assert!(r.plans_equal, "{}: row invariant broken", r.name);
    }
    let dp_min = results
        .iter()
        .filter(|r| r.path == "dp")
        .map(|r| r.speedup)
        .fold(f64::INFINITY, f64::min);
    let greedy_min = results
        .iter()
        .filter(|r| r.path == "greedy")
        .map(|r| r.speedup)
        .fold(f64::INFINITY, f64::min);
    println!(
        "worst-case speedup: dp {dp_min:.2}x, greedy {greedy_min:.2}x \
         (target: dp >= 5x, greedy >= 1x)"
    );

    section("L3 microbench — Hadar decision latency (optimised)");
    for &n in &[16usize, 64, 256, 1024] {
        let nodes_per_type = (n / 12).max(1);
        let cluster = ClusterSpec::scaled(nodes_per_type, 4);
        let trace = generate(&TraceConfig {
            n_jobs: n,
            seed: 3,
            all_at_start: true,
            max_gpus: 4,
            ..Default::default()
        });
        let jobs = materialize(&trace, &cluster, 3);
        let mut queue = JobQueue::new();
        for j in jobs {
            queue.admit(j).unwrap();
        }
        let active = queue.active_at(0.0);
        Bencher::new(&format!("hadar_decision_{n}jobs"))
            .warmup(1)
            .iters(5)
            .run(|| {
                let mut hadar = Hadar::with_config(HadarConfig::default());
                let ctx = RoundCtx {
                    round: 0,
                    now: 0.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    delta: None,
                    cluster: &cluster,
                };
                hadar.schedule(&ctx).scheduled_jobs().len()
            });
    }

    section("L3 microbench — HadarE round planning (5 nodes)");
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-12", &cluster, 1.0).unwrap();
    let ids = ForkIds { max_job_count: 64 };
    let mut tracker = JobTracker::new(ids);
    let mut queue = JobQueue::new();
    for j in &jobs {
        tracker.register(
            j.id,
            j.total_iters(),
            &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
        );
        queue.admit(j.clone()).unwrap();
    }
    Bencher::new("hadare_plan_round_m12")
        .warmup(2)
        .iters(20)
        .run(|| {
            let mut planner = HadarE::new(5);
            let ctx = RoundCtx {
                round: 0,
                now: 0.0,
                slot_secs: 90.0,
                horizon: 1e7,
                queue: &queue,
                active: &[],
                delta: None,
                cluster: &cluster,
            };
            planner.plan_round(&ctx, &tracker).scheduled_jobs().len()
        });
}
