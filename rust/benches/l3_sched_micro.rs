//! Microbenchmarks of the L3 scheduler hot path (the §Perf targets):
//! one Hadar scheduling decision at several queue sizes, FIND_ALLOC-level
//! throughput, and the HadarE round planner.
//!
//! Run: `cargo bench --bench l3_sched_micro`

use hadar::cluster::spec::ClusterSpec;
use hadar::forking::forker::ForkIds;
use hadar::forking::tracker::JobTracker;
use hadar::jobs::queue::JobQueue;
use hadar::sched::hadar::{Hadar, HadarConfig};
use hadar::sched::hadare::HadarE;
use hadar::sched::{RoundCtx, Scheduler};
use hadar::trace::philly::{generate, TraceConfig};
use hadar::trace::workload::{materialize, physical_jobs};
use hadar::util::bench::{section, Bencher};

fn main() {
    section("L3 microbench — Hadar decision latency");
    for &n in &[16usize, 64, 256, 1024] {
        let nodes_per_type = (n / 12).max(1);
        let cluster = ClusterSpec::scaled(nodes_per_type, 4);
        let trace = generate(&TraceConfig {
            n_jobs: n,
            seed: 3,
            all_at_start: true,
            max_gpus: 4,
            ..Default::default()
        });
        let jobs = materialize(&trace, &cluster, 3);
        let mut queue = JobQueue::new();
        for j in jobs {
            queue.admit(j);
        }
        let active = queue.active_at(0.0);
        Bencher::new(&format!("hadar_decision_{n}jobs"))
            .warmup(1)
            .iters(5)
            .run(|| {
                let mut hadar = Hadar::with_config(HadarConfig::default());
                let ctx = RoundCtx {
                    round: 0,
                    now: 0.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    cluster: &cluster,
                };
                hadar.schedule(&ctx).scheduled_jobs().len()
            });
    }

    section("L3 microbench — HadarE round planning (5 nodes)");
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-12", &cluster, 1.0).unwrap();
    let ids = ForkIds { max_job_count: 64 };
    let mut tracker = JobTracker::new(ids);
    let mut queue = JobQueue::new();
    for j in &jobs {
        tracker.register(
            j.id,
            j.total_iters(),
            &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
        );
        queue.admit(j.clone());
    }
    Bencher::new("hadare_plan_round_m12")
        .warmup(2)
        .iters(20)
        .run(|| {
            let mut planner = HadarE::new(5);
            let ctx = RoundCtx {
                round: 0,
                now: 0.0,
                slot_secs: 90.0,
                horizon: 1e7,
                queue: &queue,
                active: &[],
                cluster: &cluster,
            };
            planner.plan_round(&ctx, &tracker).scheduled_jobs().len()
        });
}
