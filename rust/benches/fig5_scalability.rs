//! Bench: Fig. 5 — per-round scheduling time vs active jobs (32 → 2048)
//! for Hadar (full + incremental) and Gavel.
//! Run: `cargo bench --bench fig5_scalability`

use hadar::figures::fig5;
use hadar::util::bench::section;

fn main() {
    section("Fig. 5 — scheduling-time scalability (32..2048 jobs)");
    let scales = [32, 64, 128, 256, 512, 1024, 2048];
    let pts = fig5::run(&scales);
    println!("{}", fig5::render(&pts));
    let frac: Vec<String> = pts
        .iter()
        .map(|p| format!("{}:{:.0}%", p.jobs, p.change_fraction * 100.0))
        .collect();
    println!("rounds with allocation changes (incremental mode): {}",
             frac.join(" "));
    println!("paper §IV-B: ~30% of rounds change allocations on average");
}
