//! Bench: Fig. 1 motivational example — regenerates the round-by-round
//! Gavel vs Hadar comparison and times it.
//! Run: `cargo bench --bench fig1_motivation`

use hadar::figures::fig1;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 1 — motivational example (Gavel vs Hadar)");
    let f = Bencher::new("fig1_motivation").warmup(1).iters(5).run(fig1::run);
    println!("{}", fig1::render(&f));
}
