//! Bench: serial vs parallel execution of a 16-scenario sweep grid
//! (the built-in `demo16` spec: 4 schedulers x 2 slots x 2 seeds over a
//! scaled Philly trace on sim60).
//! Run: `cargo bench --bench sweep_throughput`.

use hadar::expt::artifact::{self, ScenarioRecord};
use hadar::expt::runner;
use hadar::expt::spec::SweepSpec;
use hadar::util::bench::section;
use std::time::Instant;

fn main() {
    let spec = SweepSpec::demo();
    let n = spec.n_scenarios();
    section(&format!(
        "sweep_throughput — {n}-scenario grid, serial vs parallel"
    ));

    let t0 = Instant::now();
    let serial = runner::run_sweep(&spec, 1).expect("serial sweep");
    let serial_secs = t0.elapsed().as_secs_f64();

    let workers = runner::default_workers();
    let t0 = Instant::now();
    let parallel = runner::run_sweep(&spec, workers).expect("parallel sweep");
    let parallel_secs = t0.elapsed().as_secs_f64();

    let rec_s: Vec<ScenarioRecord> =
        serial.iter().map(ScenarioRecord::from_run).collect();
    let rec_p: Vec<ScenarioRecord> =
        parallel.iter().map(ScenarioRecord::from_run).collect();
    assert_eq!(
        artifact::canonical_jsonl(&rec_s),
        artifact::canonical_jsonl(&rec_p),
        "parallel execution must not change results"
    );

    println!("scenarios            {n}");
    println!("serial   (1 worker)  {serial_secs:>8.3} s");
    println!("parallel ({workers} workers) {parallel_secs:>8.3} s");
    println!(
        "speedup              {:.2}x (results byte-identical)",
        serial_secs / parallel_secs.max(1e-9)
    );
}
