//! Bench: Fig. 6 — Hadar vs HadarE round-by-round node occupancy on the
//! 5-node testbed (M-3 mix).
//! Run: `cargo bench --bench fig6_rounds`

use hadar::figures::fig6;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 6 — round timelines, Hadar vs HadarE (testbed5, M-3)");
    let f = Bencher::new("fig6_rounds").warmup(1).iters(5).run(fig6::run);
    println!("{}", fig6::render(&f));
}
