//! Bench: Table IV — inference quality of models trained under HadarE
//! (forking + §V-B consolidation) vs Hadar (no forking), with REAL
//! transformer training executed through the AOT-compiled HLO artifacts
//! (run `make artifacts` first).
//! Run: `cargo bench --bench table4_quality`

use hadar::exec::emulation::EmulationConfig;
use hadar::figures::table4;
use hadar::runtime::Manifest;
use hadar::sim::engine::SimConfig;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Table IV — inference quality, forking vs no forking (M-5)");
    let manifest = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("SKIPPED: {e} — run `make artifacts` first");
            return;
        }
    };
    let cfg = EmulationConfig {
        sim: SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 2_000,
            horizon: 1e7,
        },
        steps_scale: 0.01,
        max_real_steps_per_round: 200,
        lr: 0.1,
        seed: 42,
    };
    let t4 = Bencher::new("table4_real_training")
        .warmup(0)
        .iters(1)
        .run(|| table4::run(&manifest, &cfg).expect("emulation"));
    println!("{}", table4::render(&t4));
}
