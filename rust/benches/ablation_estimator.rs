//! Ablation: Eq. (10) initial-throughput estimation quality and the
//! online-refinement loop (§V-A) — how fast the EMA estimator converges to
//! ground truth, and what scheduling quality costs a cold start incurs.
//!
//! Run: `cargo bench --bench ablation_estimator`

use hadar::cluster::gpu::{GpuType, PcieGen};
use hadar::jobs::model::DlModel;
use hadar::jobs::throughput::{estimate, OnlineEstimator};
use hadar::util::bench::section;
use hadar::util::rng::Rng;
use hadar::util::table::Table;

fn main() {
    section("Ablation — Eq. (10) estimator + online refinement");

    // Ground truth: Eq. (10) perturbed by +-30% (a "real" cluster whose
    // nodes deviate from the spec-sheet model).
    let mut rng = Rng::new(99);
    let pairs: Vec<(DlModel, GpuType, PcieGen)> = DlModel::TABLE3
        .iter()
        .flat_map(|&m| {
            [GpuType::TitanRtx, GpuType::T4, GpuType::T400,
             GpuType::Rtx3090, GpuType::RtxA2000]
                .into_iter()
                .map(move |g| (m, g, PcieGen::Gen3))
        })
        .collect();
    let truth: Vec<f64> = pairs
        .iter()
        .map(|&(m, g, p)| estimate(m, g, p) * rng.range_f(0.7, 1.3))
        .collect();
    let truth_fn = |pairs: &[(DlModel, GpuType, PcieGen)],
                    truth: &[f64],
                    m: DlModel,
                    g: GpuType| {
        pairs
            .iter()
            .zip(truth)
            .find(|((pm, pg, _), _)| *pm == m && *pg == g)
            .map(|(_, &t)| t)
            .unwrap()
    };

    let mut t = Table::new(&["observations/pair", "mean |rel err|"]);
    for &obs in &[0usize, 1, 2, 4, 8, 16] {
        let mut est = OnlineEstimator::new(0.5);
        for (i, &(m, g, _)) in pairs.iter().enumerate() {
            for _ in 0..obs {
                // Noisy measurements around truth (+-10%).
                let meas = truth[i] * rng.range_f(0.9, 1.1);
                est.observe(m, g, meas);
            }
        }
        let err = est.relative_error(&pairs, |m, g| {
            truth_fn(&pairs, &truth, m, g)
        });
        t.row(&[obs.to_string(), format!("{:.1}%", err * 100.0)]);
    }
    println!("{}", t.render());
    println!(
        "paper §V-A: Eq. (10) gives 'a reasonable estimate … improved \
         progressively in the course of training' — the error column shows \
         the cold-start gap closing as rounds report measurements."
    );
}
