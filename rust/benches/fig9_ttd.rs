//! Bench: Fig. 9 — total time duration of Gavel/Hadar/HadarE across the
//! seven workload mixes on both clusters.
//! Run: `cargo bench --bench fig9_ttd`

use hadar::figures::physical;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 9 — TTD across workload mixes (aws5 + testbed5)");
    let p = Bencher::new("fig9_grid")
        .warmup(0)
        .iters(1)
        .run(|| physical::run(360.0));
    println!("{}", physical::render_fig9(&p));
}
