//! Bench: Fig. 3 — GPU resource utilisation of the four schedulers on the
//! 480-job Philly-shaped trace over the 60-GPU simulated cluster.
//! Run: `cargo bench --bench fig3_gru` (env HADAR_FULL_TRACE=1 for the
//! paper-magnitude run; the default is scaled for a single-core sandbox).

use hadar::figures::trace_eval::{self, TraceEvalConfig};
use hadar::util::bench::{section, Bencher};

fn main() {
    let full = std::env::var("HADAR_FULL_TRACE").is_ok();
    let cfg = TraceEvalConfig {
        n_jobs: 480,
        seed: 42,
        slot_secs: 360.0,
        hours_scale: if full { 1.0 } else { 0.25 },
    };
    section("Fig. 3 — GPU resource utilisation (480 jobs, sim60)");
    let te = Bencher::new("fig3_trace_eval")
        .warmup(0)
        .iters(1)
        .run(|| trace_eval::run(&cfg));
    println!("{}", trace_eval::render_fig3(&te));
}
