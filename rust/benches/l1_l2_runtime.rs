//! Microbenchmarks of the L1/L2 runtime hot path: PJRT train-step latency
//! per model variant (the fused fwd+bwd+SGD HLO containing the Pallas
//! kernels), eval-step latency, and consolidation cost.
//!
//! Run: `make artifacts && cargo bench --bench l1_l2_runtime`

use hadar::runtime::{
    consolidate_states, Manifest, Runtime, Trainer,
};
use hadar::util::bench::{section, Bencher};

fn main() {
    let manifest = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("SKIPPED: {e} — run `make artifacts` first");
            return;
        }
    };
    let rt = Runtime::cpu().expect("pjrt cpu");
    println!("platform: {}", rt.platform());

    section("L2 — train_step latency per variant (fused fwd+bwd+SGD HLO)");
    for name in ["tiny", "small", "medium"] {
        let Some(v) = manifest.variant(name) else { continue };
        let exe = rt.load_train(v).expect("compile");
        let mut trainer =
            Trainer::new(rt.init_state(v, 1), v.vocab, 1, 0.1);
        Bencher::new(&format!("train_step_{name} ({} params)",
                              v.param_count))
            .warmup(2)
            .iters(10)
            .run(|| trainer.run_steps(&exe, 1).expect("step"));
    }

    section("L2 — eval_step latency");
    for name in ["tiny", "small"] {
        let Some(v) = manifest.variant(name) else { continue };
        let eval = rt.load_eval(v).expect("compile eval");
        let trainer = Trainer::new(rt.init_state(v, 2), v.vocab, 2, 0.1);
        let mut rng = hadar::util::rng::Rng::new(3);
        let toks = trainer.corpus.batch(&mut rng, v.batch, v.seq + 1);
        Bencher::new(&format!("eval_step_{name}"))
            .warmup(2)
            .iters(10)
            .run(|| {
                eval.eval(&trainer.state, &toks, v.batch, v.seq + 1)
                    .expect("eval")
            });
    }

    section("L3 — consolidation (weight averaging) cost");
    for name in ["tiny", "medium"] {
        let Some(v) = manifest.variant(name) else { continue };
        let a = Trainer::new(rt.init_state(v, 4), v.vocab, 4, 0.1);
        let b = Trainer::new(rt.init_state(v, 5), v.vocab, 5, 0.1);
        Bencher::new(&format!("consolidate_2x_{name}"))
            .warmup(1)
            .iters(10)
            .run(|| {
                consolidate_states(&[&a.state, &b.state], &[1.0, 1.0], v)
                    .expect("consolidate")
                    .len()
            });
    }
}
