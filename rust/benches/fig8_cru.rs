//! Bench: Fig. 8 — cluster resource utilisation of Gavel/Hadar/HadarE on
//! the AWS and testbed clusters across the seven workload mixes.
//! Run: `cargo bench --bench fig8_cru`

use hadar::figures::physical;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 8 — CRU across workload mixes (aws5 + testbed5)");
    let p = Bencher::new("fig8_grid")
        .warmup(0)
        .iters(1)
        .run(|| physical::run(360.0));
    println!("{}", physical::render_fig8(&p));
}
