//! Bench: Fig. 4 — completion CDF and total time duration of the four
//! schedulers on the 480-job trace.
//! Run: `cargo bench --bench fig4_ttd_cdf`

use hadar::figures::trace_eval::{self, TraceEvalConfig};
use hadar::util::bench::{section, Bencher};

fn main() {
    let full = std::env::var("HADAR_FULL_TRACE").is_ok();
    let cfg = TraceEvalConfig {
        n_jobs: 480,
        seed: 42,
        slot_secs: 360.0,
        hours_scale: if full { 1.0 } else { 0.25 },
    };
    section("Fig. 4 — completion CDF + TTD (480 jobs, sim60)");
    let te = Bencher::new("fig4_trace_eval")
        .warmup(0)
        .iters(1)
        .run(|| trace_eval::run(&cfg));
    println!("{}", trace_eval::render_fig4(&te));
}
