//! Bench: Fig. 12 — Hadar's CRU vs slot time {90,180,360,720}s over the
//! workload mixes on both clusters.
//! Run: `cargo bench --bench fig12_slot_hadar`

use hadar::figures::slots;
use hadar::util::bench::{section, Bencher};

fn main() {
    section("Fig. 12 — Hadar CRU vs slot time");
    let s = Bencher::new("fig12_sweep")
        .warmup(0)
        .iters(1)
        .run(|| slots::run("hadar"));
    println!("{}", slots::render(&s));
}
