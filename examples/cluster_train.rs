//! End-to-end driver (the full-stack proof): the M-5 workload mix on the
//! emulated five-node heterogeneous testbed, scheduled by Hadar and
//! HadarE, with **real transformer training** executed through the
//! AOT-compiled HLO artifacts via PJRT — all three layers composing:
//!
//!   L3 rust scheduler/tracker -> L2 jax train_step HLO -> L1 pallas
//!   attention/FFN kernels (lowered inside the same HLO).
//!
//! Prints per-job loss curves, scheduling metrics, and the Table IV
//! inference-quality comparison.
//!
//! Run: `make artifacts && cargo run --release --example cluster_train`
//! (pass `--steps-scale 0.02` to train longer.)

use hadar::cluster::spec::ClusterSpec;
use hadar::exec::emulation::{
    run_hadare_emulation, run_scheduler_emulation, EmulationConfig,
};
use hadar::exec::quality::evaluate_quality;
use hadar::figures::table4;
use hadar::jobs::model::QualityMetric;
use hadar::runtime::Manifest;
use hadar::sched::hadar::Hadar;
use hadar::sim::engine::SimConfig;
use hadar::trace::workload::physical_jobs;
use hadar::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps_scale = args
        .iter()
        .position(|a| a == "--steps-scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.01);

    let manifest = Manifest::load(Manifest::default_dir()).map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first")
    })?;
    let cfg = EmulationConfig {
        sim: SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 2_000,
            horizon: 1e7,
        },
        steps_scale,
        max_real_steps_per_round: 200,
        lr: 0.1,
        seed: 42,
    };
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-5", &cluster, 1.0).unwrap();
    println!("cluster: {} ({} nodes)", cluster.name, cluster.nodes.len());
    println!("workload: M-5 = <IC, LM, LT, RS, MM>, steps_scale={steps_scale}");

    println!("\n== HadarE (forking) — real training via PJRT ==");
    let t0 = std::time::Instant::now();
    let forked = run_hadare_emulation(&jobs, &cluster, &manifest, &cfg, None)?;
    println!(
        "virtual TTD {:.0}s, CRU {:.0}%, rounds {}, {} real steps in {:.1}s wall",
        forked.sim.ttd,
        forked.sim.gru * 100.0,
        forked.sim.rounds,
        forked.total_real_steps,
        t0.elapsed().as_secs_f64()
    );

    println!("\n== Hadar (no forking) — real training via PJRT ==");
    let t0 = std::time::Instant::now();
    let mut hadar = Hadar::new();
    let unforked =
        run_scheduler_emulation(&jobs, &mut hadar, &cluster, &manifest, &cfg)?;
    println!(
        "virtual TTD {:.0}s, CRU {:.0}%, rounds {}, {} real steps in {:.1}s wall",
        unforked.sim.ttd,
        unforked.sim.gru * 100.0,
        unforked.sim.rounds,
        unforked.total_real_steps,
        t0.elapsed().as_secs_f64()
    );

    println!("\n== loss curves (HadarE) ==");
    for model in &forked.models {
        let job = jobs.iter().find(|j| j.id == model.job).unwrap();
        let curve: Vec<String> = model
            .losses
            .iter()
            .step_by((model.losses.len() / 8).max(1))
            .map(|(s, l)| format!("{s}:{l:.2}"))
            .collect();
        println!("  {} ({:<12}) steps={:<4} loss {}",
                 model.job, job.model.name(), model.real_steps,
                 curve.join(" -> "));
    }

    println!("\n== Table IV — inference quality, forking vs no forking ==");
    let pairs: Vec<_> = jobs.iter().map(|j| (j.id, j.model)).collect();
    let report = evaluate_quality(&pairs, &forked.models, &unforked.models,
                                  &manifest, cfg.seed, cfg.seed ^ 0xEEAA)?;
    let t4 = table4::Table4 {
        report,
        hadare_ttd: forked.sim.ttd,
        hadar_ttd: unforked.sim.ttd,
        real_steps: forked.total_real_steps + unforked.total_real_steps,
    };
    println!("{}", table4::render(&t4));

    // Summary table.
    let mut t = Table::new(&["metric", "HadarE", "Hadar", "ratio"]);
    t.row(&[
        "virtual TTD (s)".into(),
        format!("{:.0}", forked.sim.ttd),
        format!("{:.0}", unforked.sim.ttd),
        format!("{:.2}x", unforked.sim.ttd / forked.sim.ttd),
    ]);
    t.row(&[
        "CRU".into(),
        format!("{:.0}%", forked.sim.gru * 100.0),
        format!("{:.0}%", unforked.sim.gru * 100.0),
        format!("{:.2}x", forked.sim.gru / unforked.sim.gru),
    ]);
    let mean_jct = |m: &std::collections::BTreeMap<_, f64>| {
        m.values().sum::<f64>() / m.len().max(1) as f64
    };
    t.row(&[
        "mean JCT (s)".into(),
        format!("{:.0}", mean_jct(&forked.sim.jct)),
        format!("{:.0}", mean_jct(&unforked.sim.jct)),
        format!("{:.2}x",
                mean_jct(&unforked.sim.jct) / mean_jct(&forked.sim.jct)),
    ]);
    let _ = QualityMetric::Acc;
    println!("{}", t.render());
    Ok(())
}
