//! Quickstart: the paper's §II-A motivational example in one binary.
//!
//! Three DL jobs on a 2xV100 + 3xP100 + 1xK80 cluster, scheduled by Gavel
//! (job-level heterogeneity awareness: single GPU type per job per round)
//! vs Hadar (task-level: mixed types allowed). Prints the round-by-round
//! timelines and the Fig. 1 headline numbers.
//!
//! Run: `cargo run --release --example quickstart`

use hadar::figures::{fig1, workloads};

fn main() {
    println!("{}", workloads::render_table2());
    println!("{}", workloads::render_table3());

    println!("== Fig. 1 — motivational example: Gavel vs Hadar ==");
    let f = fig1::run();
    println!("{}", fig1::render(&f));
}
