//! Physical-cluster mixes (paper §VI, Figs. 8-12): CRU / TTD / JCT of
//! Gavel vs Hadar vs HadarE over the seven workload mixes on the AWS and
//! testbed clusters, plus the slot-time sweeps.
//!
//! Run: `cargo run --release --example physical_mixes [-- --slots]`

use hadar::figures::{physical, slots};

fn main() {
    println!("running Figs. 8-10 grid (2 clusters x 7 mixes x 3 schedulers)");
    let p = physical::run(360.0);
    println!("{}", physical::render_fig8(&p));
    println!("{}", physical::render_fig9(&p));
    println!("{}", physical::render_fig10(&p));

    if std::env::args().any(|a| a == "--slots") {
        println!("\nrunning Figs. 11-12 slot sweeps");
        let se = slots::run("hadare");
        println!("{}", slots::render(&se));
        let sh = slots::run("hadar");
        println!("{}", slots::render(&sh));
    } else {
        println!("(pass --slots for the Fig. 11/12 slot-time sweeps)");
    }
}
