//! Trace-driven simulation (paper §IV): a Philly-shaped trace on the
//! 15-node / 60-GPU simulated cluster under YARN-CS, Tiresias, Gavel, and
//! Hadar. Regenerates the Fig. 3 (GRU) and Fig. 4 (completion CDF / TTD)
//! comparisons, plus the Fig. 5 scalability sweep.
//!
//! Run: `cargo run --release --example trace_sim [-- --jobs 480 --full]`
//! (the default is a scaled-down trace so the example finishes quickly;
//! pass `--full` for the paper-magnitude 480-job run).

use hadar::figures::{fig5, trace_eval};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let jobs = args
        .iter()
        .position(|a| a == "--jobs")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(if full { 480 } else { 120 });

    let cfg = trace_eval::TraceEvalConfig {
        n_jobs: jobs,
        seed: 42,
        slot_secs: 360.0,
        hours_scale: if full { 1.0 } else { 0.25 },
    };
    println!("simulating {jobs} jobs on sim60 (hours_scale={})...",
             cfg.hours_scale);
    let te = trace_eval::run(&cfg);

    println!("\n== Fig. 3 — GPU resource utilisation ==");
    println!("{}", trace_eval::render_fig3(&te));
    println!("\n== Fig. 4 — completion CDF + TTD ==");
    println!("{}", trace_eval::render_fig4(&te));

    println!("\n== Fig. 5 — scheduling-time scalability ==");
    let scales: &[usize] = if full {
        &[32, 64, 128, 256, 512, 1024, 2048]
    } else {
        &[32, 64, 128, 256]
    };
    let pts = fig5::run(scales);
    println!("{}", fig5::render(&pts));
}
