"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests`` asserts
``assert_allclose(kernel(...), ref(...))`` across a hypothesis-driven sweep of
shapes and dtypes; this file is therefore the single source of truth for the
kernels' semantics.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Scaled dot-product attention oracle.

    Args:
      q, k, v: ``[batch*heads, seq, d_head]`` arrays.
      causal: apply a lower-triangular mask when True.

    Returns:
      ``[batch*heads, seq, d_head]`` attention output, f32.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        seq_q, seq_k = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


def ffn_ref(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
            w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Fused feed-forward oracle: GELU(x @ w1 + b1) @ w2 + b2.

    Args:
      x: ``[tokens, d_model]``.
      w1: ``[d_model, d_ff]``; b1: ``[d_ff]``.
      w2: ``[d_ff, d_model]``; b2: ``[d_model]``.
    """
    x = x.astype(jnp.float32)
    h = x @ w1.astype(jnp.float32) + b1.astype(jnp.float32)
    # tanh-approximated GELU (matches the kernel).
    g = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return g @ w2.astype(jnp.float32) + b2.astype(jnp.float32)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm oracle over the last axis."""
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
