"""Layer-1 Pallas kernels: blocked fused causal attention, fwd + bwd.

This is the compute hot-spot of the Layer-2 transformer model
(``python/compile/model.py``). The kernels are written for the TPU memory
model even though this sandbox can only *execute* them under
``interpret=True`` (the CPU PJRT plugin cannot run Mosaic custom-calls):

* **Forward** grid iterates over ``(batch*heads, seq blocks)``; each program
  streams one ``[BLOCK_Q, d_head]`` query tile from HBM into VMEM via its
  BlockSpec while K and V for the whole sequence stay resident
  (``d_head <= 64``, ``seq <= 512`` keeps the footprint well under the
  ~16 MiB VMEM budget — see DESIGN.md §Hardware-Adaptation).
* **Backward** grid iterates over ``batch*heads`` only: one program
  recomputes the score/softmax tile for its head (flash-style
  rematerialisation — probabilities are never written to HBM) and emits
  dQ/dK/dV in a single pass, avoiding cross-program accumulation.
* The matmuls are shaped ``[m, d] x [d, n]`` so they map onto the MXU
  systolic array; softmax/masking run on the VPU in f32.
* What a CUDA flash-attention kernel expresses with threadblocks +
  shared-memory tiles is expressed here with the grid + BlockSpecs: the
  HBM->VMEM schedule is the index_map, not explicit ``__shared__`` loads.

Reverse-mode autodiff through ``pallas_call`` is not supported by this JAX
build, so the pair is stitched together with ``jax.custom_vjp``.

Numerics are validated against ``ref.attention_ref`` (forward) and jnp
autodiff of the oracle (backward) by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
_NEG_INF = -1e30  # python float: jnp scalars become captured consts in pallas kernels


def _fwd_kernel(rows_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float,
                causal: bool):
    """One grid step: attend one query tile against the full K/V.

    Refs (all VMEM):
      rows_ref: [block_q]      absolute row indices of this query tile
                               (blocked iota input; autodiff-safe substitute
                               for ``pl.program_id``).
      q_ref: [1, block_q, d]   query tile for this (bh, qblock) program.
      k_ref: [1, seq, d]       full keys for this batch-head.
      v_ref: [1, seq, d]       full values.
      o_ref: [1, block_q, d]   output tile.
    """
    q = q_ref[0].astype(jnp.float32)          # [block_q, d]
    k = k_ref[0].astype(jnp.float32)          # [seq, d]
    v = v_ref[0].astype(jnp.float32)          # [seq, d]

    # MXU matmul: [block_q, d] x [d, seq] -> [block_q, seq]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        seq = k.shape[0]
        row = rows_ref[...][:, None]          # [block_q, 1] absolute rows
        col = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], seq), 1)
        scores = jnp.where(row >= col, scores, _NEG_INF)

    # Numerically-stable softmax on the VPU.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)

    # MXU matmul: [block_q, seq] x [seq, d] -> [block_q, d]
    o_ref[0] = jnp.dot(probs, v,
                       preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *,
                scale: float, causal: bool):
    """Backward for one batch-head: recompute probs, emit dQ/dK/dV.

    All refs are [1, seq, d]. The [seq, seq] score/prob tiles live only in
    VMEM/registers (seq<=512 -> 1 MiB f32), the flash-attention trade.
    """
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    seq = q.shape[0]

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (seq, seq), 1)
        scores = jnp.where(row >= col, scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)   # [seq, seq]

    dv = jnp.dot(probs.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    # softmax VJP: ds = probs * (dp - sum(dp * probs, axis=-1))
    ds = probs * (dp - jnp.sum(dp * probs, axis=-1, keepdims=True))
    dq = jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale
    dk = jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale

    dq_ref[0] = dq.astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _attention_fwd_call(q, k, v, causal: bool, block_q: int):
    bh, seq, d = q.shape
    grid = (bh, seq // block_q)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal)
    rows = jnp.arange(seq, dtype=jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, i: (i,)),
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,  # CPU PJRT gate; see module docstring.
    )(rows, q, k, v)


def _attention_bwd_call(q, k, v, do, causal: bool):
    bh, seq, d = q.shape
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_bwd_kernel, scale=scale, causal=causal)
    spec = pl.BlockSpec((1, seq, d), lambda b: (b, 0, 0))
    shape = jax.ShapeDtypeStruct((bh, seq, d), q.dtype)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[shape, shape, shape],
        interpret=True,
    )(q, k, v, do)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention(q, k, v, causal: bool, block_q: int):
    return _attention_fwd_call(q, k, v, causal, block_q)


def _attention_vjp_fwd(q, k, v, causal, block_q):
    return _attention_fwd_call(q, k, v, causal, block_q), (q, k, v)


def _attention_vjp_bwd(causal, block_q, res, do):
    q, k, v = res
    dq, dk, dv = _attention_bwd_call(q, k, v, do, causal)
    return dq, dk, dv


_attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, block_q: int | None = None) -> jnp.ndarray:
    """Blocked causal attention via Pallas (differentiable).

    Args:
      q, k, v: ``[bh, seq, d_head]`` with ``bh = batch*heads``.
      causal: lower-triangular masking.
      block_q: query-block size; must divide seq (default: min(seq, 64)).

    Returns:
      ``[bh, seq, d_head]`` output with q's dtype.
    """
    bh, seq, d = q.shape
    if block_q is None:
        block_q = min(seq, DEFAULT_BLOCK_Q)
    assert seq % block_q == 0, f"seq={seq} not divisible by block_q={block_q}"
    return _attention(q, k, v, causal, block_q)


def vmem_footprint_bytes(seq: int, d: int, block_q: int | None = None,
                         dtype_bytes: int = 4) -> Tuple[int, int]:
    """Estimated VMEM bytes resident per program instance (fwd, bwd).

    Used by DESIGN/EXPERIMENTS to argue the kernels fit the ~16 MiB VMEM
    budget on real TPUs.
    """
    if block_q is None:
        block_q = min(seq, DEFAULT_BLOCK_Q)
    fwd = (block_q * d + 2 * seq * d + block_q * seq + block_q * d
           ) * dtype_bytes
    bwd = (4 * seq * d + 2 * seq * seq + 3 * seq * d) * dtype_bytes
    return fwd, bwd
