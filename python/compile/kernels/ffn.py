"""Layer-1 Pallas kernels: fused feed-forward block (linear+GELU+linear), fwd+bwd.

The forward kernel fuses the transformer MLP so the ``[tokens, d_ff]``
intermediate never round-trips through HBM: each grid step loads one
``[BLOCK_T, d_model]`` token tile plus both weight matrices into VMEM,
computes ``GELU(x @ w1 + b1) @ w2 + b2`` on the MXU/VPU, and writes one
output tile.

The backward kernel runs as a single program (grid=()) that recomputes the
GELU intermediate (rematerialisation — it is never stored) and emits all
five input gradients in one pass; this sidesteps cross-program weight-grad
accumulation, which interpret-mode Pallas cannot express without
``program_id`` (whose autodiff rule is unsupported in this JAX build).

VMEM budget (f32): forward tile + w1 + w2 + intermediate =
``(BLOCK_T*d + 2*d*f + BLOCK_T*f) * 4`` bytes — for d=256, f=1024,
BLOCK_T=128 that is ~2.6 MiB; the backward single-program footprint for the
largest lowered variant (t=1024, d=256, f=512) is ~6.5 MiB. Both fit the
~16 MiB VMEM.

Reverse-mode is wired with ``jax.custom_vjp``; validated against
``ref.ffn_ref`` and its jnp autodiff by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128
_C0 = 0.7978845608028654  # sqrt(2/pi)
_C1 = 0.044715


def _gelu(h):
    return 0.5 * h * (1.0 + jnp.tanh(_C0 * (h + _C1 * h * h * h)))


def _gelu_grad(h):
    u = _C0 * (h + _C1 * h * h * h)
    t = jnp.tanh(u)
    du = _C0 * (1.0 + 3.0 * _C1 * h * h)
    return 0.5 * (1.0 + t) + 0.5 * h * (1.0 - t * t) * du


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # [block_t, d]
    w1 = w1_ref[...].astype(jnp.float32)        # [d, f]
    b1 = b1_ref[...].astype(jnp.float32)        # [f]
    w2 = w2_ref[...].astype(jnp.float32)        # [f, d]
    b2 = b2_ref[...].astype(jnp.float32)        # [d]
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1
    g = _gelu(h)
    o_ref[...] = (jnp.dot(g, w2, preferred_element_type=jnp.float32)
                  + b2).astype(o_ref.dtype)


def _bwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, dout_ref,
                dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    b1 = b1_ref[...].astype(jnp.float32)
    w2 = w2_ref[...].astype(jnp.float32)
    dout = dout_ref[...].astype(jnp.float32)

    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1
    g = _gelu(h)
    dg = jnp.dot(dout, w2.T, preferred_element_type=jnp.float32)
    dh = dg * _gelu_grad(h)

    dx_ref[...] = jnp.dot(dh, w1.T,
                          preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dw1_ref[...] = jnp.dot(x.T, dh,
                           preferred_element_type=jnp.float32).astype(dw1_ref.dtype)
    db1_ref[...] = jnp.sum(dh, axis=0).astype(db1_ref.dtype)
    dw2_ref[...] = jnp.dot(g.T, dout,
                           preferred_element_type=jnp.float32).astype(dw2_ref.dtype)
    db2_ref[...] = jnp.sum(dout, axis=0).astype(db2_ref.dtype)


def _ffn_fwd_call(x, w1, b1, w2, b2, block_t: int):
    t, d = x.shape
    f = w1.shape[1]
    return pl.pallas_call(
        _fwd_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def _ffn_bwd_call(x, w1, b1, w2, dout):
    t, d = x.shape
    f = w1.shape[1]
    shapes = [
        jax.ShapeDtypeStruct((t, d), x.dtype),
        jax.ShapeDtypeStruct((d, f), w1.dtype),
        jax.ShapeDtypeStruct((f,), b1.dtype),
        jax.ShapeDtypeStruct((f, d), w2.dtype),
        jax.ShapeDtypeStruct((d,), w2.dtype),
    ]
    return pl.pallas_call(_bwd_kernel, out_shape=shapes, interpret=True)(
        x, w1, b1, w2, dout)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ffn(x, w1, b1, w2, b2, block_t: int):
    return _ffn_fwd_call(x, w1, b1, w2, b2, block_t)


def _ffn_vjp_fwd(x, w1, b1, w2, b2, block_t):
    return _ffn_fwd_call(x, w1, b1, w2, b2, block_t), (x, w1, b1, w2)


def _ffn_vjp_bwd(block_t, res, dout):
    x, w1, b1, w2 = res
    dx, dw1, db1, dw2, db2 = _ffn_bwd_call(x, w1, b1, w2, dout)
    return dx, dw1, db1, dw2, db2


_ffn.defvjp(_ffn_vjp_fwd, _ffn_vjp_bwd)


def ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray,
        block_t: int | None = None) -> jnp.ndarray:
    """Fused MLP over a ``[tokens, d_model]`` input (differentiable).

    ``tokens`` must be divisible by ``block_t`` (default min(tokens, 128)).
    """
    t, _ = x.shape
    if block_t is None:
        block_t = min(t, DEFAULT_BLOCK_T)
    assert t % block_t == 0, f"tokens={t} not divisible by block_t={block_t}"
    return _ffn(x, w1, b1, w2, b2, block_t)


def vmem_footprint_bytes(d: int, f: int, t: int,
                         block_t: int = DEFAULT_BLOCK_T,
                         dtype_bytes: int = 4) -> Tuple[int, int]:
    """Estimated per-instance VMEM bytes (fwd, bwd). See module docstring."""
    fwd = (block_t * d + 2 * d * f + block_t * f + f + d) * dtype_bytes
    bwd = (3 * t * d + 2 * d * f + 2 * t * f + 2 * f + d) * dtype_bytes
    return fwd, bwd
