"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Run once at build time (``make artifacts``); the Rust binary is then fully
self-contained — Python never executes on the scheduling/training path.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

  {variant}_train.hlo.txt   train_step: (tokens i32[B,S+1], lr f32[],
                            P params..., P momenta...) ->
                            (loss f32[], P new params..., P new momenta...)
  {variant}_eval.hlo.txt    eval_step: (tokens, P params...) -> (loss, acc)
  manifest.json             the Rust-side contract: per-variant model config,
                            flat parameter order/shapes/init specs, artifact
                            file names, VMEM footprint estimates.
  model.hlo.txt             symlink-equivalent copy of the default variant's
                            train artifact (Makefile staleness anchor).
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import attention as attn_k
from .kernels import ffn as ffn_k

DEFAULT_VARIANTS = ["tiny", "small", "medium"]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def init_spec(name: str) -> dict:
    """Init rule for one parameter (mirrors model.init_params); the Rust
    runtime re-creates initial parameters from this spec with its own
    deterministic PRNG."""
    if name.endswith(".g"):
        return {"kind": "ones"}
    if name.endswith((".b", "b1", "b2")):
        return {"kind": "zeros"}
    return {"kind": "normal"}  # scale resolved per-shape below


def lower_variant(cfg: M.ModelConfig, out_dir: str) -> dict:
    """Lower train/eval for one variant; return its manifest entry."""
    specs = M.param_specs(cfg)
    tok_shape = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    lr_shape = jax.ShapeDtypeStruct((), jnp.float32)
    param_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]

    t0 = time.time()
    train_lowered = jax.jit(
        lambda t, l, *fl: M.train_step(cfg, t, l, *fl)).lower(
            tok_shape, lr_shape, *param_shapes, *param_shapes)
    train_txt = to_hlo_text(train_lowered)
    train_file = f"{cfg.name}_train.hlo.txt"
    with open(os.path.join(out_dir, train_file), "w") as f:
        f.write(train_txt)

    eval_lowered = jax.jit(
        lambda t, *ps: M.eval_step(cfg, t, *ps)).lower(
            tok_shape, *param_shapes)
    eval_txt = to_hlo_text(eval_lowered)
    eval_file = f"{cfg.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, eval_file), "w") as f:
        f.write(eval_txt)
    dt = time.time() - t0

    params = []
    for name, shape in specs:
        spec = init_spec(name)
        if spec["kind"] == "normal":
            spec["scale"] = 0.02 if "emb" in name else 1.0 / math.sqrt(shape[0])
        params.append({"name": name, "shape": list(shape), **spec})

    attn_fwd, attn_bwd = attn_k.vmem_footprint_bytes(cfg.seq, cfg.d_head)
    tokens = cfg.batch * cfg.seq
    ffn_fwd, ffn_bwd = ffn_k.vmem_footprint_bytes(cfg.d_model, cfg.d_ff,
                                                  tokens)
    print(f"  {cfg.name}: {cfg.param_count()} params, lowered in {dt:.1f}s "
          f"(train {len(train_txt)//1024} KiB, eval {len(eval_txt)//1024} KiB)")
    return {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq": cfg.seq, "batch": cfg.batch,
        },
        "param_count": cfg.param_count(),
        "params": params,
        "train_hlo": train_file,
        "eval_hlo": eval_file,
        "train_inputs": {
            "tokens": [cfg.batch, cfg.seq + 1],
            "lr": [],
            "n_params": len(specs),
        },
        "vmem_estimate_bytes": {
            "attention_fwd": attn_fwd, "attention_bwd": attn_bwd,
            "ffn_fwd": ffn_fwd, "ffn_bwd": ffn_bwd,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy anchor path (model.hlo.txt)")
    ap.add_argument("--variants", default=",".join(DEFAULT_VARIANTS))
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    variants = [v for v in args.variants.split(",") if v]
    manifest = {"format": 1, "variants": {}}
    print(f"lowering variants: {variants} -> {out_dir}")
    for v in variants:
        cfg = M.VARIANTS[v]
        manifest["variants"][v] = lower_variant(cfg, out_dir)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Makefile staleness anchor: copy of the default variant's train HLO.
    anchor = os.path.join(out_dir, "model.hlo.txt")
    default = manifest["variants"][variants[0]]["train_hlo"]
    with open(os.path.join(out_dir, default)) as src, open(anchor, "w") as dst:
        dst.write(src.read())
    print(f"wrote manifest + anchor to {out_dir}")


if __name__ == "__main__":
    main()
