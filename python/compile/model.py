"""Layer-2: decoder-only transformer LM — fwd/bwd/SGD as one jitted function.

This is the *DL training job substrate* of the Hadar/HadarE reproduction: the
paper schedules opaque DL training jobs; here every job is an instance of this
model (at a size class mapped from Table II/III — see ``VARIANTS``), trained
with real gradients. The hot-spots (attention, MLP) call the Layer-1 Pallas
kernels so they lower into the same HLO module.

The public entry points are ``train_step`` and ``eval_step``; ``aot.py``
lowers them once per model variant to HLO text that the Rust runtime
(``rust/src/runtime``) loads and executes via PJRT. Python never runs at
training time.

Parameter layout
----------------
Parameters and SGD-momentum buffers are *flat ordered lists* of arrays; the
ordering is defined by ``param_specs`` and recorded in
``artifacts/manifest.json``, which is the contract with the Rust side (it
allocates, checkpoints, and weight-averages parameters by that order).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention as pallas_attention
from .kernels.ffn import ffn as pallas_ffn
from .kernels.ref import layernorm_ref as layernorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one transformer-LM variant."""
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        return sum(int(math.prod(s)) for _, s in param_specs(self))


# Size classes map the paper's Table II/III workloads onto what a single CPU
# core can actually train (DESIGN.md documents the substitution). The five
# physical-cluster models (IC/LM/LT/RS/MM) are assigned variants in
# rust/src/jobs/model.rs.
VARIANTS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
                        d_ff=128, seq=64, batch=8),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=2,
                         n_heads=4, d_ff=256, seq=64, batch=8),
    "medium": ModelConfig("medium", vocab=1024, d_model=256, n_layers=4,
                          n_heads=4, d_ff=512, seq=128, batch=8),
    # 100M-class config for completeness; lowered on demand only (too slow to
    # train for hundreds of steps on this single-core sandbox).
    "xl": ModelConfig("xl", vocab=32768, d_model=768, n_layers=12, n_heads=12,
                      d_ff=3072, seq=256, batch=8),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """The (name, shape) list defining the flat parameter order."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    specs += [("lnf.g", (cfg.d_model,)), ("lnf.b", (cfg.d_model,))]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Deterministic initialisation matching ``param_specs`` order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", "b1", "b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            scale = 0.02 if "emb" in name else 1.0 / math.sqrt(fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _unflatten(cfg: ModelConfig, flat: Sequence[jnp.ndarray]) -> dict:
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


def forward(cfg: ModelConfig, flat_params: Sequence[jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits for ``tokens [batch, seq]`` -> ``[batch, seq, vocab]``."""
    p = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = layernorm(x, p[pre + "ln1.g"], p[pre + "ln1.b"])
        hm = h.reshape(b * s, cfg.d_model)
        q = (hm @ p[pre + "wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        k = (hm @ p[pre + "wk"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        v = (hm @ p[pre + "wv"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        # -> [b*heads, seq, d_head] for the Pallas kernel.
        q = q.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, s, cfg.d_head)
        k = k.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, s, cfg.d_head)
        v = v.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, s, cfg.d_head)
        att = pallas_attention(q, k, v, causal=True)
        att = att.reshape(b, cfg.n_heads, s, cfg.d_head).transpose(0, 2, 1, 3)
        att = att.reshape(b * s, cfg.d_model) @ p[pre + "wo"]
        x = x + att.reshape(b, s, cfg.d_model)

        h2 = layernorm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        ff = pallas_ffn(h2.reshape(b * s, cfg.d_model), p[pre + "w1"],
                        p[pre + "b1"], p[pre + "w2"], p[pre + "b2"])
        x = x + ff.reshape(b, s, cfg.d_model)

    x = layernorm(x, p["lnf.g"], p["lnf.b"])
    # Tied output head: logits = x @ tok_emb^T.
    return x @ p["tok_emb"].T


def loss_fn(cfg: ModelConfig, flat_params: Sequence[jnp.ndarray],
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy. ``tokens`` is ``[batch, seq+1]``."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat_params, inp)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_step(cfg: ModelConfig, tokens: jnp.ndarray, lr: jnp.ndarray,
               *flat: jnp.ndarray):
    """One SGD-momentum step.

    Positional layout (this is the AOT/HLO contract):
      tokens [batch, seq+1] i32, lr f32 scalar,
      then P parameter arrays, then P momentum arrays.
    Returns (loss, new_params..., new_momentum...) as a flat tuple.
    """
    n = len(flat) // 2
    params, moms = list(flat[:n]), list(flat[n:])
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens))(params)
    mu = jnp.float32(0.9)
    new_params, new_moms = [], []
    for pa, mo, gr in zip(params, moms, grads):
        nm = mu * mo + gr
        new_moms.append(nm)
        new_params.append(pa - lr * nm)
    return tuple([loss] + new_params + new_moms)


def eval_step(cfg: ModelConfig, tokens: jnp.ndarray, *params: jnp.ndarray):
    """Evaluation: (mean CE loss, top-1 next-token accuracy)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, list(params), inp)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == tgt).astype(jnp.float32))
    return loss, acc
