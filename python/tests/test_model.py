"""L2 correctness: transformer model shapes, loss behaviour, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.VARIANTS["tiny"]


def _tokens(key, cfg=CFG, extra=1):
    return jax.random.randint(jax.random.PRNGKey(key),
                              (cfg.batch, cfg.seq + extra), 0, cfg.vocab)


def test_param_specs_cover_init():
    params = M.init_params(CFG)
    specs = M.param_specs(CFG)
    assert len(params) == len(specs)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_param_count_matches():
    assert CFG.param_count() == sum(int(np.prod(p.shape))
                                    for p in M.init_params(CFG))


def test_layernorm_params_init():
    params = M.init_params(CFG)
    for p, (name, _) in zip(params, M.param_specs(CFG)):
        if name.endswith(".g"):
            np.testing.assert_array_equal(p, np.ones_like(p))
        if name.endswith((".b", "b1", "b2")):
            np.testing.assert_array_equal(p, np.zeros_like(p))


def test_forward_shape():
    params = M.init_params(CFG)
    tok = _tokens(0, extra=0)
    logits = M.forward(CFG, params, tok)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)


def test_initial_loss_near_uniform():
    """Untrained CE should sit near log(vocab)."""
    params = M.init_params(CFG)
    loss = M.loss_fn(CFG, params, _tokens(1))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_train_step_reduces_loss_on_fixed_batch():
    params = M.init_params(CFG)
    moms = [jnp.zeros_like(p) for p in params]
    tok = _tokens(2)
    step = jax.jit(lambda t, l, *fl: M.train_step(CFG, t, l, *fl))
    n = len(params)
    out = step(tok, jnp.float32(0.1), *params, *moms)
    first = float(out[0])
    for _ in range(10):
        out = step(tok, jnp.float32(0.1), *out[1:1 + n], *out[1 + n:])
    assert float(out[0]) < first - 0.5


def test_train_step_is_deterministic():
    params = M.init_params(CFG)
    moms = [jnp.zeros_like(p) for p in params]
    tok = _tokens(3)
    step = jax.jit(lambda t, l, *fl: M.train_step(CFG, t, l, *fl))
    o1 = step(tok, jnp.float32(0.05), *params, *moms)
    o2 = step(tok, jnp.float32(0.05), *params, *moms)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))
    np.testing.assert_array_equal(np.asarray(o1[1]), np.asarray(o2[1]))


def test_momentum_buffers_update():
    params = M.init_params(CFG)
    moms = [jnp.zeros_like(p) for p in params]
    n = len(params)
    out = M.train_step(CFG, _tokens(4), jnp.float32(0.1), *params, *moms)
    new_moms = out[1 + n:]
    assert any(float(jnp.abs(m).max()) > 0 for m in new_moms)


def test_eval_step_outputs():
    params = M.init_params(CFG)
    loss, acc = M.eval_step(CFG, _tokens(5), *params)
    assert loss.shape == () and acc.shape == ()
    assert 0.0 <= float(acc) <= 1.0


def test_eval_matches_loss_fn():
    params = M.init_params(CFG)
    tok = _tokens(6)
    loss, _ = M.eval_step(CFG, tok, *params)
    np.testing.assert_allclose(float(loss),
                               float(M.loss_fn(CFG, params, tok)), rtol=1e-6)


def test_weight_average_of_identical_copies_is_identity():
    """The HadarE consolidation no-op case: averaging k identical copies."""
    params = M.init_params(CFG)
    avg = [sum([p] * 3) / 3.0 for p in params]
    tok = _tokens(7)
    l1 = M.loss_fn(CFG, params, tok)
    l2 = M.loss_fn(CFG, avg, tok)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def _structured_tokens(seed, cfg=CFG):
    """Sequences following a shared next = cur + 1 (mod vocab) rule, with
    per-seed random offsets: learnable structure that generalises across
    batches (unlike uniform-random tokens)."""
    starts = jax.random.randint(jax.random.PRNGKey(1000 + seed),
                                (cfg.batch, 1), 0, cfg.vocab)
    ramp = jnp.arange(cfg.seq + 1)[None, :]
    return (starts + ramp) % cfg.vocab


def test_consolidated_copies_still_learn():
    """Two copies trained on different batches, averaged: held-out loss drops.

    This is the core assumption behind HadarE's aggregate+consolidate
    (paper §V-B); the integration-scale version runs in Rust, this guards
    the numeric substrate."""
    params = M.init_params(CFG)
    moms = [jnp.zeros_like(p) for p in params]
    n = len(params)
    step = jax.jit(lambda t, l, *fl: M.train_step(CFG, t, l, *fl))
    heldout = _structured_tokens(99)
    base_loss = float(M.loss_fn(CFG, params, heldout))
    copies = []
    for seed in (10, 11):
        out = step(_structured_tokens(seed), jnp.float32(0.1), *params, *moms)
        for _ in range(5):
            out = step(_structured_tokens(seed), jnp.float32(0.1),
                       *out[1:1 + n], *out[1 + n:])
        copies.append(list(out[1:1 + n]))
    avg = [(a + b) / 2.0 for a, b in zip(*copies)]
    assert float(M.loss_fn(CFG, avg, heldout)) < base_loss


@pytest.mark.parametrize("name", ["tiny", "small", "medium", "xl"])
def test_variant_configs_consistent(name):
    cfg = M.VARIANTS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.seq % min(cfg.seq, 64) == 0
    assert cfg.param_count() > 0


def test_xl_variant_is_100m_class():
    assert M.VARIANTS["xl"].param_count() > 80_000_000
