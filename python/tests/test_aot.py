"""AOT pipeline: lowered HLO text is parseable, manifest is complete."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrips_simple_fn():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    txt = aot.to_hlo_text(lowered)
    assert "HloModule" in txt
    assert "ENTRY" in txt


def test_init_spec_rules():
    assert aot.init_spec("layer0.ln1.g") == {"kind": "ones"}
    assert aot.init_spec("layer0.ln1.b") == {"kind": "zeros"}
    assert aot.init_spec("layer0.b1") == {"kind": "zeros"}
    assert aot.init_spec("tok_emb") == {"kind": "normal"}
    assert aot.init_spec("layer0.wq") == {"kind": "normal"}


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_has_variants(self, manifest):
        assert manifest["format"] == 1
        assert "tiny" in manifest["variants"]

    def test_manifest_param_order_matches_model(self, manifest):
        for name, entry in manifest["variants"].items():
            cfg = M.VARIANTS[name]
            specs = M.param_specs(cfg)
            assert len(entry["params"]) == len(specs)
            for rec, (pname, shape) in zip(entry["params"], specs):
                assert rec["name"] == pname
                assert tuple(rec["shape"]) == shape

    def test_artifact_files_exist_and_are_hlo(self, manifest):
        for entry in manifest["variants"].values():
            for key in ("train_hlo", "eval_hlo"):
                path = os.path.join(ART, entry[key])
                assert os.path.exists(path), path
                with open(path) as f:
                    head = f.read(200)
                assert "HloModule" in head

    def test_normal_init_has_scale(self, manifest):
        for entry in manifest["variants"].values():
            for rec in entry["params"]:
                if rec["kind"] == "normal":
                    assert rec["scale"] > 0

    def test_vmem_estimates_under_budget(self, manifest):
        for entry in manifest["variants"].values():
            for v in entry["vmem_estimate_bytes"].values():
                assert v < 16 * 2**20
