"""L1 correctness: Pallas kernels vs the pure-jnp oracle (``ref.py``).

Hypothesis sweeps shapes/dtypes; every property asserts ``assert_allclose``
against the oracle — this is the core correctness signal for the kernels
that end up inside every AOT-lowered training artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ffn as F
from compile.kernels import ref as R


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- attention

@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 6),
    seq_pow=st.integers(3, 7),          # seq in {8..128}
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(bh, seq_pow, d, causal, seed):
    seq = 2 ** seq_pow
    q = _rand(seed, (bh, seq, d), jnp.float32)
    k = _rand(seed + 1, (bh, seq, d), jnp.float32)
    v = _rand(seed + 2, (bh, seq, d), jnp.float32)
    out = A.attention(q, k, v, causal=causal)
    ref = R.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    bh=st.integers(1, 3),
    seq=st.sampled_from([16, 64]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_grads_match_ref(bh, seq, d, causal, seed):
    q = _rand(seed, (bh, seq, d), jnp.float32)
    k = _rand(seed + 1, (bh, seq, d), jnp.float32)
    v = _rand(seed + 2, (bh, seq, d), jnp.float32)
    f_ker = lambda *a: jnp.sum(jnp.sin(A.attention(*a, causal=causal)))
    f_ref = lambda *a: jnp.sum(jnp.sin(R.attention_ref(*a, causal=causal)))
    gk = jax.grad(f_ker, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_q", [8, 16, 32, 64])
def test_attention_block_size_invariance(block_q):
    """Output must not depend on the VMEM tiling choice."""
    q = _rand(7, (2, 64, 16), jnp.float32)
    k = _rand(8, (2, 64, 16), jnp.float32)
    v = _rand(9, (2, 64, 16), jnp.float32)
    base = A.attention(q, k, v, block_q=64)
    out = A.attention(q, k, v, block_q=block_q)
    np.testing.assert_allclose(out, base, rtol=1e-6, atol=1e-6)


def test_attention_bf16_inputs():
    q = _rand(1, (2, 32, 16), jnp.bfloat16)
    k = _rand(2, (2, 32, 16), jnp.bfloat16)
    v = _rand(3, (2, 32, 16), jnp.bfloat16)
    out = A.attention(q, k, v)
    ref = R.attention_ref(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(jnp.float32), ref, rtol=2e-2,
                               atol=2e-2)


def test_attention_rejects_bad_block():
    q = _rand(1, (1, 48, 8), jnp.float32)
    with pytest.raises(AssertionError):
        A.attention(q, q, q, block_q=32)


def test_attention_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    q = _rand(11, (1, 32, 8), jnp.float32)
    k = _rand(12, (1, 32, 8), jnp.float32)
    v = _rand(13, (1, 32, 8), jnp.float32)
    base = A.attention(q, k, v, causal=True)
    k2 = k.at[:, 20:, :].set(99.0)
    v2 = v.at[:, 20:, :].set(-99.0)
    pert = A.attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(base[:, :20, :], pert[:, :20, :], rtol=1e-6,
                               atol=1e-6)
    assert not np.allclose(base[:, 20:, :], pert[:, 20:, :])


def test_attention_vmem_budget():
    """The lowered sizes must stay under a 16 MiB VMEM budget."""
    for seq in (64, 128, 256, 512):
        for d in (16, 32, 64):
            fwd, bwd = A.vmem_footprint_bytes(seq, d)
            assert fwd < 16 * 2**20, (seq, d, fwd)
            assert bwd < 16 * 2**20, (seq, d, bwd)


# ---------------------------------------------------------------------- ffn

@settings(max_examples=20, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_ffn_matches_ref(t_blocks, d, f, seed):
    t = 128 * t_blocks
    x = _rand(seed, (t, d), jnp.float32)
    w1 = 0.2 * _rand(seed + 1, (d, f), jnp.float32)
    b1 = 0.1 * _rand(seed + 2, (f,), jnp.float32)
    w2 = 0.2 * _rand(seed + 3, (f, d), jnp.float32)
    b2 = 0.1 * _rand(seed + 4, (d,), jnp.float32)
    out = F.ffn(x, w1, b1, w2, b2)
    ref = R.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_ffn_grads_match_ref(seed):
    x = _rand(seed, (128, 16), jnp.float32)
    w1 = 0.2 * _rand(seed + 1, (16, 32), jnp.float32)
    b1 = 0.1 * _rand(seed + 2, (32,), jnp.float32)
    w2 = 0.2 * _rand(seed + 3, (32, 16), jnp.float32)
    b2 = 0.1 * _rand(seed + 4, (16,), jnp.float32)
    fk = lambda *a: jnp.sum(jnp.cos(F.ffn(*a)))
    fr = lambda *a: jnp.sum(jnp.cos(R.ffn_ref(*a)))
    gk = jax.grad(fk, argnums=(0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_ffn_block_size_invariance():
    x = _rand(20, (256, 32), jnp.float32)
    w1 = 0.2 * _rand(21, (32, 64), jnp.float32)
    b1 = jnp.zeros(64)
    w2 = 0.2 * _rand(22, (64, 32), jnp.float32)
    b2 = jnp.zeros(32)
    base = F.ffn(x, w1, b1, w2, b2, block_t=256)
    for bt in (32, 64, 128):
        np.testing.assert_allclose(F.ffn(x, w1, b1, w2, b2, block_t=bt),
                                   base, rtol=1e-6, atol=1e-6)


def test_ffn_vmem_budget():
    fwd, bwd = F.vmem_footprint_bytes(256, 512, 1024)
    assert fwd < 16 * 2**20
    assert bwd < 16 * 2**20
